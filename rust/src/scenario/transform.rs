//! Trace transformers — the combinator pipeline a scenario phase applies
//! to its base trace (DESIGN.md §7.2).
//!
//! Each transformer is a pure, deterministic function `Trace → Trace`
//! (randomness comes from the phase's seeded [`Rng`]), and each preserves
//! the trace invariants [`Trace::validate`] checks: items stay inside
//! `[0, n_items)`, servers inside `[0, n_servers)`, and time stays
//! non-decreasing. They compose in the canonical order of
//! [`Transform::CANONICAL_ORDER`]: time-warps first (rate scaling,
//! diurnal modulation), then content rewrites (bundle churn, flash crowd,
//! catalog rollover), then routing rewrites (outage re-routing, hot
//! server skew) — so a
//! spec's transformer set always means the same pipeline regardless of
//! key order in the TOML.

use crate::trace::model::{Request, Trace};
use crate::util::Rng;

/// One trace transformer. Window fields (`start_frac` / `end_frac`) are
/// fractions of the phase's time span; the transformer is active for
/// requests with `t ∈ [t0 + start·span, t0 + end·span)`.
#[derive(Debug, Clone, PartialEq)]
pub enum Transform {
    /// Compress (factor > 1) or stretch (factor < 1) inter-arrival times:
    /// the request *content* is untouched, only the arrival rate changes
    /// relative to the Δt expiry window — the keep-vs-drop economics knob
    /// (arXiv:1312.0499).
    RateScale { factor: f64 },
    /// Diurnal rate modulation: time-warp arrivals so the instantaneous
    /// rate follows `λ(u) = λ0 · (1 + amplitude · sin(2πu/period))`
    /// (time-varying volume, arXiv:1803.03914). `amplitude ∈ [0, 0.95]`.
    Diurnal { period: f64, amplitude: f64 },
    /// Flash crowd: inside the window, each request is redirected with
    /// probability `frac` to a small breaking-news hot set of `n_hot`
    /// items (drawn once per phase).
    FlashCrowd {
        start_frac: f64,
        end_frac: f64,
        frac: f64,
        n_hot: usize,
    },
    /// Bundle churn injection: every `period` time units the whole item
    /// id space rotates by `shift` (a popularity relabeling). Co-access
    /// structure is preserved, but every learned clique goes stale —
    /// exactly the merge/split/adjust stress (Algorithms 3-5).
    BundleChurn { period: f64, shift: u32 },
    /// Catalog rollover: from `at_frac` of the span onward, a sampled
    /// `frac` of the catalog is swapped for other titles (a random
    /// permutation of the sampled subset) — new releases displace old.
    CatalogRollover { at_frac: f64, frac: f64 },
    /// Region outage: inside the window, a contiguous block of `n_down`
    /// servers goes dark and its traffic re-routes `n_down` servers ahead
    /// (mod m), concentrating load on the survivors.
    Outage {
        start_frac: f64,
        end_frac: f64,
        n_down: u32,
    },
    /// Hot-shard skew: inside the window, each request is redirected
    /// with probability `frac` to a contiguous block of `n_hot` servers
    /// (drawn once per phase). Under modular placement the block lands
    /// on a handful of shards, so occupancy and queue depth go lopsided
    /// — the elastic rebalance stress (DESIGN.md §13.5).
    ServerSkew {
        start_frac: f64,
        end_frac: f64,
        frac: f64,
        n_hot: u32,
    },
}

impl Transform {
    /// Pipeline position of each variant; [`sort_canonical`] orders a
    /// transformer set by it.
    pub const CANONICAL_ORDER: [&'static str; 7] = [
        "rate_scale",
        "diurnal",
        "bundle_churn",
        "flash_crowd",
        "catalog_rollover",
        "outage",
        "server_skew",
    ];

    /// Stable spec-grammar name (also the key prefix in phase tables).
    pub fn name(&self) -> &'static str {
        match self {
            Transform::RateScale { .. } => "rate_scale",
            Transform::Diurnal { .. } => "diurnal",
            Transform::BundleChurn { .. } => "bundle_churn",
            Transform::FlashCrowd { .. } => "flash_crowd",
            Transform::CatalogRollover { .. } => "catalog_rollover",
            Transform::Outage { .. } => "outage",
            Transform::ServerSkew { .. } => "server_skew",
        }
    }

    fn rank(&self) -> usize {
        Self::CANONICAL_ORDER
            .iter()
            .position(|&n| n == self.name())
            .unwrap_or(usize::MAX)
    }

    /// Validate parameters against the universe the phase runs in.
    pub fn validate(&self, n_items: u32, n_servers: u32) -> anyhow::Result<()> {
        let window_ok = |lo: f64, hi: f64| (0.0..=1.0).contains(&lo) && lo < hi && hi <= 1.0;
        match *self {
            Transform::RateScale { factor } => {
                anyhow::ensure!(
                    factor.is_finite() && factor > 0.0,
                    "rate_scale factor must be positive (got {factor})"
                );
            }
            Transform::Diurnal { period, amplitude } => {
                anyhow::ensure!(period > 0.0, "diurnal_period must be positive");
                anyhow::ensure!(
                    (0.0..=0.95).contains(&amplitude),
                    "diurnal_amplitude must be in [0, 0.95] (got {amplitude})"
                );
            }
            Transform::FlashCrowd {
                start_frac,
                end_frac,
                frac,
                n_hot,
            } => {
                anyhow::ensure!(
                    window_ok(start_frac, end_frac),
                    "flash window [{start_frac}, {end_frac}) invalid"
                );
                anyhow::ensure!(
                    frac > 0.0 && frac <= 1.0,
                    "flash_frac must be in (0, 1] (got {frac})"
                );
                anyhow::ensure!(
                    n_hot >= 1 && n_hot <= n_items as usize,
                    "flash_items must be in [1, n_items={n_items}] (got {n_hot})"
                );
            }
            Transform::BundleChurn { period, shift } => {
                anyhow::ensure!(period > 0.0, "churn_period must be positive");
                anyhow::ensure!(
                    shift >= 1 && shift < n_items,
                    "churn_shift must be in [1, n_items={n_items}) (got {shift})"
                );
            }
            Transform::CatalogRollover { at_frac, frac } => {
                anyhow::ensure!(
                    (0.0..1.0).contains(&at_frac),
                    "rollover_at_frac must be in [0, 1) (got {at_frac})"
                );
                anyhow::ensure!(
                    frac > 0.0 && frac <= 1.0,
                    "rollover_frac must be in (0, 1] (got {frac})"
                );
            }
            Transform::Outage {
                start_frac,
                end_frac,
                n_down,
            } => {
                anyhow::ensure!(
                    window_ok(start_frac, end_frac),
                    "outage window [{start_frac}, {end_frac}) invalid"
                );
                anyhow::ensure!(
                    n_down >= 1 && 2 * n_down <= n_servers,
                    "outage_servers must be in [1, n_servers/2={}] (got {n_down})",
                    n_servers / 2
                );
            }
            Transform::ServerSkew {
                start_frac,
                end_frac,
                frac,
                n_hot,
            } => {
                anyhow::ensure!(
                    window_ok(start_frac, end_frac),
                    "skew window [{start_frac}, {end_frac}) invalid"
                );
                anyhow::ensure!(
                    frac > 0.0 && frac <= 1.0,
                    "skew_frac must be in (0, 1] (got {frac})"
                );
                anyhow::ensure!(
                    n_hot >= 1 && n_hot <= n_servers,
                    "skew_servers must be in [1, n_servers={n_servers}] (got {n_hot})"
                );
            }
        }
        Ok(())
    }

    /// Apply in place. `rng` is the phase's transformer stream — every
    /// variant draws a deterministic amount of randomness per request, so
    /// the pipeline is reproducible from the scenario seed.
    pub fn apply(&self, trace: &mut Trace, rng: &mut Rng) {
        if trace.requests.is_empty() {
            return;
        }
        let t0 = trace.requests[0].time;
        let span = (trace.requests.last().unwrap().time - t0).max(f64::MIN_POSITIVE);
        match *self {
            Transform::RateScale { factor } => {
                for r in trace.requests.iter_mut() {
                    r.time = t0 + (r.time - t0) / factor;
                }
            }
            Transform::Diurnal { period, amplitude } => {
                // Invert the integrated rate Λ(u) = u + (aP/2π)(1-cos(2πu/P)):
                // mapping tᵢ ↦ Λ⁻¹(tᵢ) turns a homogeneous stream into an
                // inhomogeneous one with rate λ0·(1 + a·sin(2πu/P)). Λ is
                // strictly increasing (Λ' = 1 + a·sin ≥ 1-a > 0), so
                // bisection from the previous solution converges.
                let two_pi = std::f64::consts::TAU;
                let lam = |u: f64| {
                    u + amplitude * period / two_pi * (1.0 - (two_pi * u / period).cos())
                };
                let mut prev_u = 0.0f64;
                let mut prev_t = 0.0f64;
                for r in trace.requests.iter_mut() {
                    let t = r.time - t0;
                    let mut lo = prev_u;
                    let mut hi = prev_u + (t - prev_t) / (1.0 - amplitude) + 1e-12;
                    for _ in 0..64 {
                        let mid = 0.5 * (lo + hi);
                        if lam(mid) < t {
                            lo = mid;
                        } else {
                            hi = mid;
                        }
                    }
                    prev_u = 0.5 * (lo + hi);
                    prev_t = t;
                    r.time = t0 + prev_u;
                }
            }
            Transform::FlashCrowd {
                start_frac,
                end_frac,
                frac,
                n_hot,
            } => {
                let mut hot: Vec<u32> = rng
                    .sample_distinct(trace.n_items as usize, n_hot)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                hot.sort_unstable();
                let (w_lo, w_hi) = (t0 + start_frac * span, t0 + end_frac * span);
                for r in trace.requests.iter_mut() {
                    if r.time < w_lo || r.time >= w_hi {
                        continue;
                    }
                    if rng.chance(frac) {
                        let k = r.items.len().min(n_hot);
                        let off = rng.below(n_hot);
                        let items: Vec<u32> =
                            (0..k).map(|j| hot[(off + j) % n_hot]).collect();
                        *r = Request::new(items, r.server, r.time);
                    }
                }
            }
            Transform::BundleChurn { period, shift } => {
                let n = trace.n_items;
                for r in trace.requests.iter_mut() {
                    let epoch = ((r.time - t0) / period).floor() as u64;
                    let rot = (epoch.wrapping_mul(shift as u64) % n as u64) as u32;
                    if rot == 0 {
                        continue;
                    }
                    let items: Vec<u32> = r
                        .items
                        .iter()
                        .map(|&d| ((d as u64 + rot as u64) % n as u64) as u32)
                        .collect();
                    *r = Request::new(items, r.server, r.time);
                }
            }
            Transform::CatalogRollover { at_frac, frac } => {
                // Sample the rolled-over subset, then permute it: old id →
                // its shuffled partner (a bijection, so ids never collide).
                let rolled: Vec<u32> =
                    (0..trace.n_items).filter(|_| rng.chance(frac)).collect();
                let mut replacement = rolled.clone();
                rng.shuffle(&mut replacement);
                let map: std::collections::HashMap<u32, u32> =
                    rolled.iter().copied().zip(replacement).collect();
                let t_cut = t0 + at_frac * span;
                for r in trace.requests.iter_mut() {
                    if r.time < t_cut || map.is_empty() {
                        continue;
                    }
                    if r.items.iter().any(|d| map.contains_key(d)) {
                        let items: Vec<u32> = r
                            .items
                            .iter()
                            .map(|d| map.get(d).copied().unwrap_or(*d))
                            .collect();
                        *r = Request::new(items, r.server, r.time);
                    }
                }
            }
            Transform::Outage {
                start_frac,
                end_frac,
                n_down,
            } => {
                let m = trace.n_servers;
                let first_down = rng.below(m as usize) as u32;
                let (w_lo, w_hi) = (t0 + start_frac * span, t0 + end_frac * span);
                for r in trace.requests.iter_mut() {
                    if r.time < w_lo || r.time >= w_hi {
                        continue;
                    }
                    // Contiguous-mod-m membership test for the down block.
                    if (r.server + m - first_down) % m < n_down {
                        r.server = (r.server + n_down) % m;
                    }
                }
            }
            Transform::ServerSkew {
                start_frac,
                end_frac,
                frac,
                n_hot,
            } => {
                let m = trace.n_servers;
                let first_hot = rng.below(m as usize) as u32;
                let (w_lo, w_hi) = (t0 + start_frac * span, t0 + end_frac * span);
                for r in trace.requests.iter_mut() {
                    if r.time < w_lo || r.time >= w_hi {
                        continue;
                    }
                    if rng.chance(frac) {
                        r.server = (first_hot + rng.below(n_hot as usize) as u32) % m;
                    }
                }
            }
        }
    }
}

/// Order a transformer set into the canonical pipeline order (stable, so
/// equal-ranked entries keep spec order).
pub fn sort_canonical(transforms: &mut [Transform]) {
    transforms.sort_by_key(|t| t.rank());
}

/// Per-variant bounded state of a streaming transform application.
#[derive(Debug, Clone)]
enum StreamState {
    /// Pure per-request map (rate scale, bundle churn).
    Stateless,
    /// Λ⁻¹ bisection warm-started from the previous arrival.
    Diurnal { prev_u: f64, prev_t: f64 },
    /// Hot set drawn once at stream start.
    FlashCrowd { hot: Vec<u32>, w_lo: f64, w_hi: f64 },
    /// Rollover bijection drawn once at stream start.
    Rollover {
        map: std::collections::HashMap<u32, u32>,
        t_cut: f64,
    },
    /// Down block drawn once at stream start.
    Outage {
        first_down: u32,
        w_lo: f64,
        w_hi: f64,
    },
    /// Hot block drawn once at stream start.
    ServerSkew {
        first_hot: u32,
        w_lo: f64,
        w_hi: f64,
    },
}

/// The streaming form of one [`Transform`] (DESIGN.md §10.3): applied
/// request by request with **bounded state** — the setup randomness
/// (flash hot set, rollover bijection, outage block) is drawn once at
/// construction, the per-request randomness comes from the same `rng`
/// stream in arrival order, and only O(1)–O(n_items) state persists
/// between requests. Given the phase's true `(t0, span)` and an `rng` at
/// the same state, a single streamed transform produces bit-identical
/// requests to the materialized [`Transform::apply`] pass (pinned by a
/// unit test below).
///
/// Chaining caveat: a materialized *pipeline* applies each transform in
/// a full pass (so later transforms see earlier ones' rewritten times
/// and a sequentially-shared rng). A streamed chain interleaves per
/// request instead — deterministic, but not bit-identical to the
/// materialized pipeline for ≥ 2 random transforms. Scenario
/// compilation therefore keeps the materialized per-phase pipeline
/// (bounded by one phase, DESIGN.md §10.3); [`TransformedSource`] is the
/// adapter for streaming single-transform workloads.
#[derive(Debug, Clone)]
pub struct StreamedTransform {
    kind: Transform,
    t0: f64,
    state: StreamState,
}

impl Transform {
    /// Begin a streaming application over a stream with universe shape
    /// `(n_items, n_servers)` spanning `[t0, t0 + span)`. The setup
    /// randomness is drawn from `rng` here, in exactly the order the
    /// materialized `apply` draws it before its pass — so a single
    /// transform streamed with the same starting rng state is
    /// draw-for-draw identical to the materialized pass.
    pub fn streamed(
        &self,
        t0: f64,
        span: f64,
        n_items: u32,
        n_servers: u32,
        rng: &mut Rng,
    ) -> StreamedTransform {
        let span = span.max(f64::MIN_POSITIVE);
        let state = match *self {
            Transform::RateScale { .. } | Transform::BundleChurn { .. } => {
                StreamState::Stateless
            }
            Transform::Diurnal { .. } => StreamState::Diurnal {
                prev_u: 0.0,
                prev_t: 0.0,
            },
            Transform::FlashCrowd {
                start_frac,
                end_frac,
                n_hot,
                ..
            } => {
                let mut hot: Vec<u32> = rng
                    .sample_distinct(n_items as usize, n_hot)
                    .into_iter()
                    .map(|i| i as u32)
                    .collect();
                hot.sort_unstable();
                StreamState::FlashCrowd {
                    hot,
                    w_lo: t0 + start_frac * span,
                    w_hi: t0 + end_frac * span,
                }
            }
            Transform::CatalogRollover { at_frac, frac } => {
                let rolled: Vec<u32> = (0..n_items).filter(|_| rng.chance(frac)).collect();
                let mut replacement = rolled.clone();
                rng.shuffle(&mut replacement);
                StreamState::Rollover {
                    map: rolled.iter().copied().zip(replacement).collect(),
                    t_cut: t0 + at_frac * span,
                }
            }
            Transform::Outage {
                start_frac,
                end_frac,
                ..
            } => StreamState::Outage {
                first_down: rng.below(n_servers as usize) as u32,
                w_lo: t0 + start_frac * span,
                w_hi: t0 + end_frac * span,
            },
            Transform::ServerSkew {
                start_frac,
                end_frac,
                ..
            } => StreamState::ServerSkew {
                first_hot: rng.below(n_servers as usize) as u32,
                w_lo: t0 + start_frac * span,
                w_hi: t0 + end_frac * span,
            },
        };
        StreamedTransform {
            kind: self.clone(),
            t0,
            state,
        }
    }
}

impl StreamedTransform {
    /// Apply to one request, drawing per-request randomness from `rng`
    /// in arrival order (matches the materialized pass draw for draw).
    /// `n_items`/`n_servers` are the stream's universe shape.
    pub fn apply(&mut self, r: &mut Request, rng: &mut Rng, n_items: u32, n_servers: u32) {
        let t0 = self.t0;
        match (&self.kind, &mut self.state) {
            (Transform::RateScale { factor }, StreamState::Stateless) => {
                r.time = t0 + (r.time - t0) / factor;
            }
            (
                Transform::Diurnal { period, amplitude },
                StreamState::Diurnal { prev_u, prev_t },
            ) => {
                let two_pi = std::f64::consts::TAU;
                let lam = |u: f64| {
                    u + amplitude * period / two_pi * (1.0 - (two_pi * u / period).cos())
                };
                let t = r.time - t0;
                let mut lo = *prev_u;
                let mut hi = *prev_u + (t - *prev_t) / (1.0 - amplitude) + 1e-12;
                for _ in 0..64 {
                    let mid = 0.5 * (lo + hi);
                    if lam(mid) < t {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                *prev_u = 0.5 * (lo + hi);
                *prev_t = t;
                r.time = t0 + *prev_u;
            }
            (
                Transform::FlashCrowd { frac, n_hot, .. },
                StreamState::FlashCrowd { hot, w_lo, w_hi },
            ) => {
                if r.time < *w_lo || r.time >= *w_hi {
                    return;
                }
                if rng.chance(*frac) {
                    let k = r.items.len().min(*n_hot);
                    let off = rng.below(*n_hot);
                    let items: Vec<u32> = (0..k).map(|j| hot[(off + j) % *n_hot]).collect();
                    *r = Request::new(items, r.server, r.time);
                }
            }
            (Transform::BundleChurn { period, shift }, StreamState::Stateless) => {
                let n = n_items;
                let epoch = ((r.time - t0) / period).floor() as u64;
                let rot = (epoch.wrapping_mul(*shift as u64) % n as u64) as u32;
                if rot == 0 {
                    return;
                }
                let items: Vec<u32> = r
                    .items
                    .iter()
                    .map(|&d| ((d as u64 + rot as u64) % n as u64) as u32)
                    .collect();
                *r = Request::new(items, r.server, r.time);
            }
            (Transform::CatalogRollover { .. }, StreamState::Rollover { map, t_cut }) => {
                if r.time < *t_cut || map.is_empty() {
                    return;
                }
                if r.items.iter().any(|d| map.contains_key(d)) {
                    let items: Vec<u32> = r
                        .items
                        .iter()
                        .map(|d| map.get(d).copied().unwrap_or(*d))
                        .collect();
                    *r = Request::new(items, r.server, r.time);
                }
            }
            (
                Transform::Outage { n_down, .. },
                StreamState::Outage {
                    first_down,
                    w_lo,
                    w_hi,
                },
            ) => {
                let m = n_servers;
                if r.time < *w_lo || r.time >= *w_hi {
                    return;
                }
                if (r.server + m - *first_down) % m < *n_down {
                    r.server = (r.server + *n_down) % m;
                }
            }
            (
                Transform::ServerSkew { frac, n_hot, .. },
                StreamState::ServerSkew { first_hot, w_lo, w_hi },
            ) => {
                let m = n_servers;
                if r.time < *w_lo || r.time >= *w_hi {
                    return;
                }
                if rng.chance(*frac) {
                    r.server = (*first_hot + rng.below(*n_hot as usize) as u32) % m;
                }
            }
            _ => unreachable!("state/kind mismatch"),
        }
    }
}

/// A [`TraceSource`](crate::trace::stream::TraceSource) adapter applying
/// streamed transforms per request — the scenario layer's bounded-memory
/// composition point (DESIGN.md §10.3). Each stage carries its own
/// deterministically derived rng stream; time-warping stages keep the
/// stream time-ordered, so downstream validation still holds.
pub struct TransformedSource<S: crate::trace::stream::TraceSource> {
    inner: S,
    stages: Vec<(StreamedTransform, Rng)>,
    meta: crate::trace::stream::TraceMeta,
}

impl<S: crate::trace::stream::TraceSource> TransformedSource<S> {
    /// Wrap `inner`, applying `transforms` (already in canonical order)
    /// over the known stream bounds `[t0, t0 + span)`. Stage *i* draws
    /// from `Rng::new(seed ^ i·golden)` — deterministic from `seed`.
    pub fn new(inner: S, transforms: &[Transform], t0: f64, span: f64, seed: u64) -> Self {
        let meta = inner.meta().clone();
        let stages = transforms
            .iter()
            .enumerate()
            .map(|(i, tr)| {
                let mut rng =
                    Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let st = tr.streamed(t0, span, meta.n_items, meta.n_servers, &mut rng);
                (st, rng)
            })
            .collect();
        Self {
            inner,
            stages,
            meta,
        }
    }
}

impl<S: crate::trace::stream::TraceSource> crate::trace::stream::TraceSource
    for TransformedSource<S>
{
    fn meta(&self) -> &crate::trace::stream::TraceMeta {
        &self.meta
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        let more = self.inner.next_chunk(buf)?;
        for r in buf.iter_mut() {
            for (stage, rng) in self.stages.iter_mut() {
                stage.apply(r, rng, self.meta.n_items, self.meta.n_servers);
            }
        }
        Ok(more)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::netflix_like;

    fn base() -> Trace {
        netflix_like(40, 20, 4_000, 9)
    }

    fn apply(t: Transform, seed: u64) -> Trace {
        let mut trace = base();
        t.validate(trace.n_items, trace.n_servers).unwrap();
        let mut rng = Rng::new(seed);
        t.apply(&mut trace, &mut rng);
        trace.validate().unwrap();
        trace
    }

    #[test]
    fn rate_scale_compresses_span() {
        let orig = base();
        let fast = apply(Transform::RateScale { factor: 4.0 }, 1);
        let orig_span = orig.requests.last().unwrap().time - orig.requests[0].time;
        let fast_span = fast.requests.last().unwrap().time - fast.requests[0].time;
        assert!((fast_span - orig_span / 4.0).abs() < 1e-6 * orig_span);
        assert_eq!(orig.requests[17].items, fast.requests[17].items);
    }

    #[test]
    fn diurnal_modulates_rate_and_keeps_order() {
        let orig = base();
        let span = orig.requests.last().unwrap().time - orig.requests[0].time;
        let period = span / 2.0;
        let warped = apply(
            Transform::Diurnal {
                period,
                amplitude: 0.8,
            },
            1,
        );
        // Count arrivals in the first rising half-period (rate > λ0)
        // vs the falling half: the warped trace must be denser early.
        let t0 = warped.requests[0].time;
        let q = period / 2.0;
        let count = |lo: f64, hi: f64| {
            warped
                .requests
                .iter()
                .filter(|r| r.time - t0 >= lo && r.time - t0 < hi)
                .count()
        };
        let peak = count(0.0, q);
        let trough = count(q, 2.0 * q);
        assert!(
            peak as f64 > 1.3 * trough as f64,
            "no modulation: peak {peak} vs trough {trough}"
        );
    }

    #[test]
    fn flash_crowd_concentrates_popularity() {
        let t = apply(
            Transform::FlashCrowd {
                start_frac: 0.25,
                end_frac: 0.75,
                frac: 0.8,
                n_hot: 3,
            },
            7,
        );
        let mut counts = vec![0usize; 40];
        for r in &t.requests {
            for &d in &r.items {
                counts[d as usize] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top3: usize = counts[..3].iter().sum();
        // ~40% of requests redirected to 3 items → they dominate.
        assert!(
            top3 as f64 > 0.3 * total as f64,
            "hot set carries {top3}/{total}"
        );
    }

    #[test]
    fn bundle_churn_rotates_hot_set() {
        let orig = base();
        let span = orig.requests.last().unwrap().time - orig.requests[0].time;
        let t = apply(
            Transform::BundleChurn {
                period: span / 4.0,
                shift: 11,
            },
            3,
        );
        let top = |reqs: &[Request]| {
            let mut c = vec![0usize; 40];
            for r in reqs {
                for &d in &r.items {
                    c[d as usize] += 1;
                }
            }
            let mut idx: Vec<usize> = (0..40).collect();
            idx.sort_unstable_by(|&a, &b| c[b].cmp(&c[a]));
            idx[..5].to_vec()
        };
        let head = top(&t.requests[..1000]);
        let tail = top(&t.requests[3000..]);
        let overlap = head.iter().filter(|i| tail.contains(i)).count();
        assert!(overlap < 5, "hot set did not rotate (overlap {overlap})");
    }

    #[test]
    fn rollover_changes_post_cut_catalog_only() {
        let orig = base();
        let t = apply(
            Transform::CatalogRollover {
                at_frac: 0.5,
                frac: 0.9,
            },
            5,
        );
        // Pre-cut requests are untouched.
        assert_eq!(orig.requests[10].items, t.requests[10].items);
        // Post-cut, a large sampled subset is remapped.
        let changed = orig
            .requests
            .iter()
            .zip(&t.requests)
            .skip(3 * orig.len() / 4)
            .filter(|(a, b)| a.items != b.items)
            .count();
        assert!(changed > orig.len() / 8, "only {changed} requests remapped");
    }

    #[test]
    fn outage_empties_down_block_inside_window() {
        let down = 5u32;
        let t = apply(
            Transform::Outage {
                start_frac: 0.3,
                end_frac: 0.7,
                n_down: down,
            },
            11,
        );
        // Recover the down block deterministically from the same stream.
        let mut rng = Rng::new(11);
        let first_down = rng.below(t.n_servers as usize) as u32;
        let t0 = t.requests[0].time;
        let span = t.requests.last().unwrap().time - t0;
        let in_block = |s: u32| (s + t.n_servers - first_down) % t.n_servers < down;
        let dark = t
            .requests
            .iter()
            .filter(|r| {
                r.time >= t0 + 0.3 * span && r.time < t0 + 0.7 * span && in_block(r.server)
            })
            .count();
        assert_eq!(dark, 0, "{dark} requests still hit the dark block");
    }

    #[test]
    fn server_skew_concentrates_routing_inside_window() {
        let hot = 2u32;
        let t = apply(
            Transform::ServerSkew {
                start_frac: 0.25,
                end_frac: 0.75,
                frac: 0.8,
                n_hot: hot,
            },
            13,
        );
        // Recover the hot block deterministically from the same stream.
        let mut rng = Rng::new(13);
        let first_hot = rng.below(t.n_servers as usize) as u32;
        let t0 = t.requests[0].time;
        let span = t.requests.last().unwrap().time - t0;
        let in_block = |s: u32| (s + t.n_servers - first_hot) % t.n_servers < hot;
        let windowed: Vec<&Request> = t
            .requests
            .iter()
            .filter(|r| r.time >= t0 + 0.25 * span && r.time < t0 + 0.75 * span)
            .collect();
        let to_hot = windowed.iter().filter(|r| in_block(r.server)).count();
        // ~80% redirected into a 2-server block (2/20 = 10% baseline).
        assert!(
            to_hot as f64 > 0.6 * windowed.len() as f64,
            "hot block carries only {to_hot}/{}",
            windowed.len()
        );
        // Outside the window, routing is untouched.
        let orig = base();
        for (a, b) in orig.requests.iter().zip(&t.requests) {
            if b.time < t0 + 0.25 * span || b.time >= t0 + 0.75 * span {
                assert_eq!(a.server, b.server);
            }
        }
    }

    #[test]
    fn transforms_are_deterministic() {
        for t in [
            Transform::RateScale { factor: 2.0 },
            Transform::FlashCrowd {
                start_frac: 0.0,
                end_frac: 1.0,
                frac: 0.5,
                n_hot: 4,
            },
            Transform::BundleChurn {
                period: 0.5,
                shift: 3,
            },
        ] {
            let a = apply(t.clone(), 42);
            let b = apply(t, 42);
            assert_eq!(a.requests, b.requests);
        }
    }

    #[test]
    fn streamed_single_transform_matches_materialized() {
        // One streamed transform with the same starting rng state is
        // draw-for-draw identical to the materialized pass — the
        // bounded-state claim of DESIGN.md §10.3, per variant.
        let variants = [
            Transform::RateScale { factor: 3.0 },
            Transform::Diurnal {
                period: 0.7,
                amplitude: 0.6,
            },
            Transform::FlashCrowd {
                start_frac: 0.2,
                end_frac: 0.8,
                frac: 0.5,
                n_hot: 4,
            },
            Transform::BundleChurn {
                period: 0.4,
                shift: 7,
            },
            Transform::CatalogRollover {
                at_frac: 0.5,
                frac: 0.6,
            },
            Transform::Outage {
                start_frac: 0.1,
                end_frac: 0.9,
                n_down: 3,
            },
            Transform::ServerSkew {
                start_frac: 0.2,
                end_frac: 0.9,
                frac: 0.7,
                n_hot: 2,
            },
        ];
        for tr in variants {
            let mut materialized = base();
            let t0 = materialized.requests[0].time;
            let span = (materialized.requests.last().unwrap().time - t0)
                .max(f64::MIN_POSITIVE);
            let (n_items, n_servers) = (materialized.n_items, materialized.n_servers);
            let mut rng_a = Rng::new(99);
            tr.apply(&mut materialized, &mut rng_a);

            let mut streamed = base();
            let mut rng_b = Rng::new(99);
            let mut st = tr.streamed(t0, span, n_items, n_servers, &mut rng_b);
            for r in streamed.requests.iter_mut() {
                st.apply(r, &mut rng_b, n_items, n_servers);
            }
            assert_eq!(
                streamed.requests,
                materialized.requests,
                "streamed {} diverged from materialized",
                tr.name()
            );
            streamed.validate().unwrap();
        }
    }

    #[test]
    fn transformed_source_streams_per_chunk() {
        use crate::trace::stream::{MemorySource, TraceSource};
        let t = base();
        let t0 = t.requests[0].time;
        let span = t.requests.last().unwrap().time - t0;
        let tr = Transform::RateScale { factor: 2.0 };

        // Materialized reference with the same derived stage rng.
        let mut reference = t.clone();
        let mut rng = Rng::new(7 ^ 0x9E37_79B9_7F4A_7C15);
        tr.apply(&mut reference, &mut rng);

        let inner = MemorySource::new(&t).with_chunk_len(97);
        let mut src = TransformedSource::new(inner, &[tr], t0, span, 7);
        assert_eq!(src.meta().n_items, t.n_items);
        let streamed = src.collect().unwrap();
        assert_eq!(streamed.requests, reference.requests);
    }

    #[test]
    fn canonical_sort_orders_pipeline() {
        let mut ts = vec![
            Transform::Outage {
                start_frac: 0.0,
                end_frac: 1.0,
                n_down: 1,
            },
            Transform::FlashCrowd {
                start_frac: 0.0,
                end_frac: 1.0,
                frac: 0.1,
                n_hot: 1,
            },
            Transform::RateScale { factor: 2.0 },
        ];
        sort_canonical(&mut ts);
        assert_eq!(ts[0].name(), "rate_scale");
        assert_eq!(ts[1].name(), "flash_crowd");
        assert_eq!(ts[2].name(), "outage");
    }

    #[test]
    fn validate_rejects_out_of_range() {
        assert!(Transform::RateScale { factor: 0.0 }.validate(10, 10).is_err());
        assert!(Transform::Diurnal {
            period: 1.0,
            amplitude: 0.99
        }
        .validate(10, 10)
        .is_err());
        assert!(Transform::FlashCrowd {
            start_frac: 0.0,
            end_frac: 1.0,
            frac: 0.5,
            n_hot: 11
        }
        .validate(10, 10)
        .is_err());
        assert!(Transform::Outage {
            start_frac: 0.0,
            end_frac: 1.0,
            n_down: 6
        }
        .validate(10, 10)
        .is_err());
        assert!(Transform::BundleChurn {
            period: 1.0,
            shift: 10
        }
        .validate(10, 10)
        .is_err());
        assert!(Transform::ServerSkew {
            start_frac: 0.0,
            end_frac: 1.0,
            frac: 0.5,
            n_hot: 11
        }
        .validate(10, 10)
        .is_err());
    }
}
