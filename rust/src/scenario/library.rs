//! Built-in scenario library (EXPERIMENTS.md §Scenarios): the
//! non-stationary regimes the paper's two static workloads cannot
//! express, written in the spec grammar itself so each doubles as a
//! reference example. `akpc scenario <name>` resolves names here.

use super::spec::ScenarioSpec;

/// `(name, one-line description, spec TOML)`.
const BUILTINS: &[(&str, &str, &str)] = &[
    (
        "flash-crowd",
        "breaking-news popularity spike on a Netflix-like catalog",
        r#"
        name = "flash-crowd"
        seed = 101
        n_items = 60
        n_servers = 600

        [phase]
        label = "warmup"
        generator = "netflix"
        requests = 25000

        [phase]
        label = "spike"
        generator = "netflix"
        requests = 30000
        flash_frac = 0.35
        flash_items = 4

        [phase]
        label = "cooldown"
        generator = "netflix"
        requests = 25000
        "#,
    ),
    (
        "diurnal",
        "day/night arrival-rate cycle (time-varying volume)",
        r#"
        name = "diurnal"
        seed = 102
        n_items = 60
        n_servers = 600

        [phase]
        label = "cycle"
        generator = "netflix"
        requests = 80000
        diurnal_period = 10.0
        diurnal_amplitude = 0.8
        "#,
    ),
    (
        "regional-outage",
        "a third of the edge servers go dark; traffic fails over",
        r#"
        name = "regional-outage"
        seed = 103
        n_items = 60
        n_servers = 600

        [phase]
        label = "steady"
        generator = "netflix"
        requests = 25000

        [phase]
        label = "outage"
        generator = "netflix"
        requests = 30000
        outage_servers = 200
        outage_start_frac = 0.1
        outage_end_frac = 0.9

        [phase]
        label = "recovery"
        generator = "netflix"
        requests = 25000
        "#,
    ),
    (
        "catalog-rollover",
        "half the Spotify-like catalog is displaced by new releases",
        r#"
        name = "catalog-rollover"
        seed = 104
        n_items = 60
        n_servers = 600

        [phase]
        label = "charts"
        generator = "spotify"
        requests = 30000

        [phase]
        label = "release-day"
        generator = "spotify"
        requests = 30000
        rollover_frac = 0.5
        rollover_at_frac = 0.3

        [phase]
        label = "new-charts"
        generator = "spotify"
        requests = 20000
        "#,
    ),
    (
        "churn-storm",
        "bundle popularity rotates every Δt: merge/split under fire",
        r#"
        name = "churn-storm"
        seed = 105
        n_items = 60
        n_servers = 600

        [phase]
        label = "calm"
        generator = "spotify"
        requests = 25000

        [phase]
        label = "storm"
        generator = "spotify"
        requests = 30000
        churn_period = 2.0
        churn_shift = 13

        [phase]
        label = "aftermath"
        generator = "spotify"
        requests = 20000
        "#,
    ),
    (
        "rate-surge",
        "request volume ramps 1x -> 4x -> 1x against a fixed Δt",
        r#"
        name = "rate-surge"
        seed = 106
        n_items = 60
        n_servers = 600

        [phase]
        label = "baseline"
        generator = "netflix"
        requests = 25000

        [phase]
        label = "surge"
        generator = "netflix"
        requests = 40000
        rate_scale = 4.0

        [phase]
        label = "relax"
        generator = "netflix"
        requests = 25000
        "#,
    ),
    (
        "autoscale-flash-crowd",
        "flash crowd at 6x arrival rate: the elastic scale-up stress",
        r#"
        name = "autoscale-flash-crowd"
        seed = 108
        n_items = 60
        n_servers = 600

        [phase]
        label = "calm"
        generator = "netflix"
        requests = 20000

        [phase]
        label = "spike"
        generator = "netflix"
        requests = 30000
        rate_scale = 6.0
        flash_frac = 0.35
        flash_items = 4

        [phase]
        label = "cooldown"
        generator = "netflix"
        requests = 20000
        "#,
    ),
    (
        "overnight-trough",
        "arrival rate falls to a quarter overnight: the scale-down stress",
        r#"
        name = "overnight-trough"
        seed = 109
        n_items = 60
        n_servers = 600

        [phase]
        label = "evening"
        generator = "netflix"
        requests = 20000

        [phase]
        label = "overnight"
        generator = "netflix"
        requests = 20000
        rate_scale = 0.25

        [phase]
        label = "morning"
        generator = "netflix"
        requests = 20000
        "#,
    ),
    (
        "hot-shard-skew",
        "traffic collapses onto a small server block: the rebalance stress",
        r#"
        name = "hot-shard-skew"
        seed = 110
        n_items = 60
        n_servers = 600

        [phase]
        label = "balanced"
        generator = "netflix"
        requests = 20000

        [phase]
        label = "skewed"
        generator = "netflix"
        requests = 30000
        skew_frac = 0.8
        skew_servers = 40
        skew_start_frac = 0.1
        skew_end_frac = 0.9

        [phase]
        label = "rebalanced"
        generator = "netflix"
        requests = 20000
        "#,
    ),
    (
        "smoke",
        "tiny three-phase mix exercising every driver path (CI)",
        r#"
        name = "smoke"
        seed = 107
        n_items = 24
        n_servers = 12

        [phase]
        label = "warm"
        generator = "netflix"
        requests = 600

        [phase]
        label = "stress"
        generator = "spotify"
        requests = 800
        flash_frac = 0.3
        flash_items = 3
        churn_period = 0.2
        churn_shift = 5
        outage_servers = 3

        [phase]
        label = "settle"
        generator = "netflix"
        requests = 600
        rate_scale = 2.0
        "#,
    ),
];

/// Names of every built-in scenario, in presentation order.
pub fn builtin_names() -> Vec<&'static str> {
    BUILTINS.iter().map(|(n, ..)| *n).collect()
}

/// The "real" scenarios the suite runner sweeps (everything except the
/// CI smoke helper).
pub fn suite_names() -> Vec<&'static str> {
    BUILTINS
        .iter()
        .map(|(n, ..)| *n)
        .filter(|&n| n != "smoke")
        .collect()
}

/// One-line description of a built-in.
pub fn describe(name: &str) -> Option<&'static str> {
    BUILTINS
        .iter()
        .find(|(n, ..)| *n == name)
        .map(|(_, d, _)| *d)
}

/// Resolve a built-in scenario by name.
pub fn builtin(name: &str) -> Option<ScenarioSpec> {
    let (_, _, toml) = BUILTINS.iter().find(|(n, ..)| *n == name)?;
    Some(
        ScenarioSpec::from_toml_str(toml)
            .unwrap_or_else(|e| panic!("built-in scenario `{name}` is invalid: {e}")),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_parses_and_matches_its_name() {
        for name in builtin_names() {
            let spec = builtin(name).expect("missing builtin");
            assert_eq!(spec.name, name);
            assert!(!spec.phases.is_empty());
            assert!(describe(name).is_some());
        }
        assert!(builtin("no-such").is_none());
        assert!(builtin_names().len() >= 7);
        assert_eq!(suite_names().len(), builtin_names().len() - 1);
        assert!(!suite_names().contains(&"smoke"));
    }

    #[test]
    fn smoke_is_small_enough_for_ci() {
        let sc = builtin("smoke").unwrap().compile(1.0).unwrap();
        assert!(sc.total_requests() <= 2_500);
        sc.concat_trace().validate().unwrap();
    }
}
