//! Scenario Lab (DESIGN.md §7): a declarative workload-scenario engine
//! sitting between trace generation and simulation.
//!
//! The paper evaluates on two *stationary* workload shapes; AKPC's
//! adaptive machinery — clique merge/split under churn (Algorithms 3-5)
//! and the Δt retention rule (Algorithm 6) — only shows its value under
//! non-stationary traffic (flash crowds, diurnal cycles, failover,
//! catalog rollovers; cf. arXiv:1803.03914, arXiv:1312.0499). This module
//! makes such regimes first-class:
//!
//! * [`spec`] — the declarative scenario grammar (TOML-lite with repeated
//!   `[phase]` tables) and its compiler to globally-timed traces;
//! * [`transform`] — the trace-transformer combinator pipeline (flash
//!   crowd, diurnal modulation, bundle churn, outage re-routing, catalog
//!   rollover, rate scaling), each also available in a bounded-state
//!   streaming form ([`StreamedTransform`] / [`TransformedSource`],
//!   DESIGN.md §10.3);
//! * [`driver`] — phased replay through the single-leader simulator and
//!   the sharded coordinator, with per-phase cost breakdowns;
//! * [`library`] — the built-in named scenarios (`akpc scenario <name>`;
//!   the suite runner in [`crate::bench::scenarios`] sweeps them).

pub mod driver;
pub mod library;
pub mod spec;
pub mod transform;

pub use driver::{run_phased, run_phased_sharded, PhaseCost, ScenarioRun};
pub use library::{builtin, builtin_names, describe, suite_names};
pub use spec::{CompiledPhase, CompiledScenario, PhaseBase, PhaseSpec, ScenarioSpec};
pub use transform::{StreamedTransform, Transform, TransformedSource};
