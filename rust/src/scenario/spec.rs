//! The declarative scenario spec (DESIGN.md §7.1): a TOML-lite document
//! with root-level universe keys and one `[phase]` table per timeline
//! phase, compiled into a [`CompiledScenario`] — the materialized,
//! globally-timed traces the replay drivers consume.
//!
//! ```toml
//! name = "flash-crowd"
//! seed = 7
//! n_items = 60
//! n_servers = 600
//!
//! [phase]
//! label = "warmup"
//! generator = "netflix"
//! requests = 20000
//!
//! [phase]
//! label = "spike"
//! generator = "netflix"
//! requests = 30000
//! flash_frac = 0.35        # transformer keys — see Transform
//! flash_items = 4
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::toml_lite::{self, Value};
use crate::trace::generator::{self, GeneratorParams, TraceKind};
use crate::trace::io as trace_io;
use crate::trace::model::Trace;
use crate::util::Rng;

use super::transform::{sort_canonical, Transform};

/// Where a phase's base trace comes from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PhaseBase {
    /// Synthetic Netflix-like preset.
    Netflix,
    /// Synthetic Spotify-like preset.
    Spotify,
    /// An `akpc-trace` CSV written by [`trace_io::write_csv`].
    Csv(String),
    /// An external Kaggle-style CSV ([`trace_io::read_external_csv`]).
    Kaggle(String),
}

impl PhaseBase {
    fn parse(s: &str) -> anyhow::Result<Self> {
        if let Some(p) = s.strip_prefix("csv:") {
            return Ok(PhaseBase::Csv(p.to_string()));
        }
        if let Some(p) = s.strip_prefix("kaggle:") {
            return Ok(PhaseBase::Kaggle(p.to_string()));
        }
        match s {
            "netflix" => Ok(PhaseBase::Netflix),
            "spotify" => Ok(PhaseBase::Spotify),
            _ => anyhow::bail!(
                "unknown generator `{s}` (expected netflix|spotify|csv:<path>|kaggle:<path>)"
            ),
        }
    }
}

/// One phase of the scenario timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpec {
    pub label: String,
    pub base: PhaseBase,
    /// Requests to generate (synthetic bases) or keep (file bases;
    /// 0 = whole file). Scaled by the compile-time `scale` factor.
    pub n_requests: usize,
    /// Transformer pipeline, already in canonical order.
    pub transforms: Vec<Transform>,
}

/// A full declarative scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub seed: u64,
    pub n_items: u32,
    pub n_servers: u32,
    pub phases: Vec<PhaseSpec>,
}

/// Pull a typed value out of a table, consuming the key.
fn take_num(map: &mut BTreeMap<String, Value>, key: &str) -> anyhow::Result<Option<f64>> {
    match map.remove(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("`{key}` must be a number")),
    }
}

fn take_str(map: &mut BTreeMap<String, Value>, key: &str) -> anyhow::Result<Option<String>> {
    match map.remove(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| anyhow::anyhow!("`{key}` must be a string")),
    }
}

/// Like [`take_num`] but insists on a non-negative integer — a bare `as`
/// cast would silently truncate fractions and saturate negatives, which
/// contradicts the parser's reject-anything-suspect policy.
fn take_uint(map: &mut BTreeMap<String, Value>, key: &str) -> anyhow::Result<Option<u64>> {
    match take_num(map, key)? {
        None => Ok(None),
        Some(v) => {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v <= u64::MAX as f64,
                "`{key}` must be a non-negative integer (got {v})"
            );
            Ok(Some(v as u64))
        }
    }
}

/// [`take_uint`] narrowed to `u32`.
fn take_u32(map: &mut BTreeMap<String, Value>, key: &str) -> anyhow::Result<Option<u32>> {
    match take_uint(map, key)? {
        None => Ok(None),
        Some(v) => {
            anyhow::ensure!(v <= u32::MAX as u64, "`{key}` {v} exceeds u32 range");
            Ok(Some(v as u32))
        }
    }
}

impl ScenarioSpec {
    /// Parse a scenario document.
    pub fn from_toml_str(text: &str) -> anyhow::Result<Self> {
        let doc = toml_lite::parse_doc(text)?;
        let mut root = doc.root;
        let name = take_str(&mut root, "name")?.unwrap_or_else(|| "scenario".to_string());
        let seed = take_uint(&mut root, "seed")?.unwrap_or(1);
        let n_items = take_u32(&mut root, "n_items")?
            .ok_or_else(|| anyhow::anyhow!("scenario needs root key `n_items`"))?;
        let n_servers = take_u32(&mut root, "n_servers")?
            .ok_or_else(|| anyhow::anyhow!("scenario needs root key `n_servers`"))?;
        if let Some(k) = root.keys().next() {
            anyhow::bail!("unknown scenario key `{k}`");
        }
        anyhow::ensure!(n_items >= 1, "n_items must be >= 1");
        anyhow::ensure!(n_servers >= 1, "n_servers must be >= 1");

        let mut phases = Vec::new();
        for (table_name, table) in doc.tables {
            anyhow::ensure!(
                table_name == "phase",
                "unknown table `[{table_name}]` (only `[phase]` is allowed)"
            );
            phases.push(Self::parse_phase(table, phases.len(), n_items, n_servers)?);
        }
        anyhow::ensure!(!phases.is_empty(), "scenario has no `[phase]` tables");
        Ok(Self {
            name,
            seed,
            n_items,
            n_servers,
            phases,
        })
    }

    /// Load from a file.
    pub fn from_toml_file(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        Self::from_toml_str(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    fn parse_phase(
        mut t: BTreeMap<String, Value>,
        index: usize,
        n_items: u32,
        n_servers: u32,
    ) -> anyhow::Result<PhaseSpec> {
        let label =
            take_str(&mut t, "label")?.unwrap_or_else(|| format!("phase-{}", index + 1));
        let base = PhaseBase::parse(
            &take_str(&mut t, "generator")?
                .ok_or_else(|| anyhow::anyhow!("phase `{label}`: missing `generator`"))?,
        )?;
        let n_requests = take_uint(&mut t, "requests")?.unwrap_or(0) as usize;
        if matches!(base, PhaseBase::Netflix | PhaseBase::Spotify) {
            anyhow::ensure!(
                n_requests >= 1,
                "phase `{label}`: synthetic base needs `requests >= 1`"
            );
        }

        // Dependent sub-keys are consumed up front so a sub-key without
        // its primary gets a targeted error, not "unknown key".
        let needs = |sub: Option<f64>, sub_key: &str, primary: &str| -> anyhow::Result<()> {
            anyhow::ensure!(
                sub.is_none(),
                "phase `{label}`: `{sub_key}` needs `{primary}`"
            );
            Ok(())
        };
        let mut transforms = Vec::new();
        if let Some(factor) = take_num(&mut t, "rate_scale")? {
            transforms.push(Transform::RateScale { factor });
        }
        let amplitude = take_num(&mut t, "diurnal_amplitude")?;
        let period = take_num(&mut t, "diurnal_period")?;
        match (amplitude, period) {
            (Some(a), Some(p)) => transforms.push(Transform::Diurnal {
                period: p,
                amplitude: a,
            }),
            (None, None) => {}
            _ => anyhow::bail!(
                "phase `{label}`: diurnal_amplitude and diurnal_period go together"
            ),
        }
        let flash_start = take_num(&mut t, "flash_start_frac")?;
        let flash_end = take_num(&mut t, "flash_end_frac")?;
        let flash_items = take_u32(&mut t, "flash_items")?;
        match take_num(&mut t, "flash_frac")? {
            Some(frac) => transforms.push(Transform::FlashCrowd {
                start_frac: flash_start.unwrap_or(0.0),
                end_frac: flash_end.unwrap_or(1.0),
                frac,
                n_hot: flash_items.unwrap_or(3) as usize,
            }),
            None => {
                needs(flash_start, "flash_start_frac", "flash_frac")?;
                needs(flash_end, "flash_end_frac", "flash_frac")?;
                needs(flash_items.map(f64::from), "flash_items", "flash_frac")?;
            }
        }
        let churn_shift = take_u32(&mut t, "churn_shift")?;
        match take_num(&mut t, "churn_period")? {
            Some(p) => transforms.push(Transform::BundleChurn {
                period: p,
                shift: churn_shift.unwrap_or(1),
            }),
            None => needs(churn_shift.map(f64::from), "churn_shift", "churn_period")?,
        }
        let rollover_at = take_num(&mut t, "rollover_at_frac")?;
        match take_num(&mut t, "rollover_frac")? {
            Some(frac) => transforms.push(Transform::CatalogRollover {
                at_frac: rollover_at.unwrap_or(0.5),
                frac,
            }),
            None => needs(rollover_at, "rollover_at_frac", "rollover_frac")?,
        }
        let outage_start = take_num(&mut t, "outage_start_frac")?;
        let outage_end = take_num(&mut t, "outage_end_frac")?;
        match take_u32(&mut t, "outage_servers")? {
            Some(n_down) => transforms.push(Transform::Outage {
                start_frac: outage_start.unwrap_or(0.0),
                end_frac: outage_end.unwrap_or(1.0),
                n_down,
            }),
            None => {
                needs(outage_start, "outage_start_frac", "outage_servers")?;
                needs(outage_end, "outage_end_frac", "outage_servers")?;
            }
        }
        let skew_start = take_num(&mut t, "skew_start_frac")?;
        let skew_end = take_num(&mut t, "skew_end_frac")?;
        let skew_servers = take_u32(&mut t, "skew_servers")?;
        match take_num(&mut t, "skew_frac")? {
            Some(frac) => transforms.push(Transform::ServerSkew {
                start_frac: skew_start.unwrap_or(0.0),
                end_frac: skew_end.unwrap_or(1.0),
                frac,
                n_hot: skew_servers.unwrap_or(1),
            }),
            None => {
                needs(skew_start, "skew_start_frac", "skew_frac")?;
                needs(skew_end, "skew_end_frac", "skew_frac")?;
                needs(skew_servers.map(f64::from), "skew_servers", "skew_frac")?;
            }
        }
        if let Some(k) = t.keys().next() {
            anyhow::bail!("phase `{label}`: unknown key `{k}`");
        }
        for tr in &transforms {
            tr.validate(n_items, n_servers)
                .map_err(|e| anyhow::anyhow!("phase `{label}`: {e}"))?;
        }
        sort_canonical(&mut transforms);
        Ok(PhaseSpec {
            label,
            base,
            n_requests,
            transforms,
        })
    }

    /// Materialize every phase at `scale` (phase lengths multiplied by it,
    /// floored at one request) into globally-timed traces. Deterministic:
    /// the same spec + scale always yields the same request stream.
    pub fn compile(&self, scale: f64) -> anyhow::Result<CompiledScenario> {
        anyhow::ensure!(
            scale.is_finite() && scale > 0.0,
            "scale must be positive (got {scale})"
        );
        let mut phases = Vec::with_capacity(self.phases.len());
        // The scenario clock: where the next phase's local t=0 lands.
        let mut clock = 0.0f64;
        for (i, ph) in self.phases.iter().enumerate() {
            let seed = phase_seed(self.seed, i);
            let want = ((ph.n_requests as f64 * scale).round() as usize).max(1);
            let mut trace = match &ph.base {
                PhaseBase::Netflix | PhaseBase::Spotify => {
                    let kind = if ph.base == PhaseBase::Netflix {
                        TraceKind::Netflix
                    } else {
                        TraceKind::Spotify
                    };
                    let mut p = match kind {
                        TraceKind::Netflix => {
                            GeneratorParams::netflix(self.n_items, self.n_servers, want)
                        }
                        TraceKind::Spotify => {
                            GeneratorParams::spotify(self.n_items, self.n_servers, want)
                        }
                    };
                    p.seed ^= seed;
                    generator::try_generate(&p, kind)?
                }
                PhaseBase::Csv(path) | PhaseBase::Kaggle(path) => {
                    let mut t = match &ph.base {
                        PhaseBase::Csv(_) => trace_io::read_csv(path)?,
                        _ => trace_io::read_external_csv(path)?,
                    };
                    anyhow::ensure!(
                        t.n_items <= self.n_items && t.n_servers <= self.n_servers,
                        "phase `{}`: file universe ({} items, {} servers) exceeds \
                         scenario universe ({}, {})",
                        ph.label,
                        t.n_items,
                        t.n_servers,
                        self.n_items,
                        self.n_servers
                    );
                    if ph.n_requests > 0 {
                        t.requests.truncate(want);
                    }
                    anyhow::ensure!(
                        !t.requests.is_empty(),
                        "phase `{}`: file trace is empty",
                        ph.label
                    );
                    // Normalize file times to a phase-local origin.
                    let t0 = t.requests[0].time;
                    for r in t.requests.iter_mut() {
                        r.time -= t0;
                    }
                    t.n_items = self.n_items;
                    t.n_servers = self.n_servers;
                    t
                }
            };

            let mut rng = Rng::new(seed ^ 0xC0FF_EE);
            for tr in &ph.transforms {
                tr.apply(&mut trace, &mut rng);
            }

            // Shift to the global timeline; advance the clock past the
            // phase by one mean inter-arrival gap so phase boundaries
            // never collapse onto each other.
            for r in trace.requests.iter_mut() {
                r.time += clock;
            }
            let (first, last) = (
                trace.requests[0].time,
                trace.requests.last().unwrap().time,
            );
            clock = last + ((last - first) / trace.len() as f64).max(1e-9);

            trace.name = format!("{}/{}", self.name, ph.label);
            trace
                .validate()
                .map_err(|e| anyhow::anyhow!("phase `{}`: {e}", ph.label))?;
            phases.push(CompiledPhase {
                label: ph.label.clone(),
                trace,
            });
        }
        Ok(CompiledScenario {
            name: self.name.clone(),
            n_items: self.n_items,
            n_servers: self.n_servers,
            phases,
            full: std::sync::OnceLock::new(),
        })
    }
}

fn phase_seed(seed: u64, index: usize) -> u64 {
    seed ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// One materialized phase: its trace carries *global* scenario times.
#[derive(Debug, Clone)]
pub struct CompiledPhase {
    pub label: String,
    pub trace: Trace,
}

/// A materialized scenario ready for the replay drivers.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    pub name: String,
    pub n_items: u32,
    pub n_servers: u32,
    pub phases: Vec<CompiledPhase>,
    /// The flattened timeline, built lazily on first use (DESIGN.md
    /// §10.4): online-policy replays walk phase by phase and never pay
    /// for the second copy of the whole timeline; only offline
    /// `prepare` and export/stats paths force it.
    full: std::sync::OnceLock<Trace>,
}

impl CompiledScenario {
    pub fn total_requests(&self) -> usize {
        self.phases.iter().map(|p| p.trace.len()).sum()
    }

    /// The whole timeline as one flat trace (offline policies' `prepare`,
    /// `trace-stats`, export). **Materializes the full concat on first
    /// call** — doubles the scenario's resident requests; phased replay
    /// of online policies deliberately never calls it.
    pub fn concat_trace(&self) -> &Trace {
        self.full.get_or_init(|| Trace {
            requests: self
                .phases
                .iter()
                .flat_map(|p| p.trace.requests.iter().cloned())
                .collect(),
            n_items: self.n_items,
            n_servers: self.n_servers,
            name: self.name.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
        name = "unit"
        seed = 5
        n_items = 30
        n_servers = 12

        [phase]
        label = "calm"
        generator = "netflix"
        requests = 800

        [phase]
        label = "storm"
        generator = "spotify"
        requests = 1200
        flash_frac = 0.5
        flash_items = 3
        churn_period = 0.2
        churn_shift = 7
        outage_servers = 2
    "#;

    #[test]
    fn parses_phases_and_canonical_order() {
        let s = ScenarioSpec::from_toml_str(SPEC).unwrap();
        assert_eq!(s.name, "unit");
        assert_eq!(s.phases.len(), 2);
        assert_eq!(s.phases[0].label, "calm");
        assert!(s.phases[0].transforms.is_empty());
        let names: Vec<_> = s.phases[1].transforms.iter().map(|t| t.name()).collect();
        assert_eq!(names, vec!["bundle_churn", "flash_crowd", "outage"]);
    }

    #[test]
    fn rejects_unknown_keys_and_tables() {
        assert!(ScenarioSpec::from_toml_str("n_items = 10\nn_servers = 2\nbogus = 1")
            .unwrap_err()
            .to_string()
            .contains("unknown scenario key"));
        let bad_phase = "n_items = 10\nn_servers = 2\n[phase]\ngenerator = \"netflix\"\n\
                         requests = 10\nwat = 3";
        assert!(ScenarioSpec::from_toml_str(bad_phase)
            .unwrap_err()
            .to_string()
            .contains("unknown key `wat`"));
        let bad_table = "n_items = 10\nn_servers = 2\n[stage]\nx = 1";
        assert!(ScenarioSpec::from_toml_str(bad_table).is_err());
        assert!(ScenarioSpec::from_toml_str("n_items = 10\nn_servers = 2").is_err());
    }

    #[test]
    fn rejects_non_integer_and_orphan_sub_keys() {
        // Negative / fractional integers error instead of silently casting.
        let neg = "n_items = 10\nn_servers = 2\n[phase]\ngenerator = \"netflix\"\n\
                   requests = -100";
        assert!(ScenarioSpec::from_toml_str(neg)
            .unwrap_err()
            .to_string()
            .contains("non-negative integer"));
        let frac = "n_items = 10.5\nn_servers = 2\n[phase]\ngenerator = \"netflix\"\n\
                    requests = 10";
        assert!(ScenarioSpec::from_toml_str(frac).is_err());
        // A dependent sub-key without its primary names the missing key.
        let orphan = "n_items = 10\nn_servers = 4\n[phase]\ngenerator = \"netflix\"\n\
                      requests = 10\nflash_start_frac = 0.2";
        let err = ScenarioSpec::from_toml_str(orphan).unwrap_err().to_string();
        assert!(err.contains("`flash_start_frac` needs `flash_frac`"), "{err}");
        let orphan2 = "n_items = 10\nn_servers = 4\n[phase]\ngenerator = \"netflix\"\n\
                       requests = 10\nchurn_shift = 3";
        let err = ScenarioSpec::from_toml_str(orphan2).unwrap_err().to_string();
        assert!(err.contains("`churn_shift` needs `churn_period`"), "{err}");
    }

    #[test]
    fn compile_is_deterministic_and_globally_timed() {
        let s = ScenarioSpec::from_toml_str(SPEC).unwrap();
        let a = s.compile(1.0).unwrap();
        let b = s.compile(1.0).unwrap();
        assert_eq!(a.total_requests(), 2000);
        assert_eq!(a.phases.len(), 2);
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            assert_eq!(pa.trace.requests, pb.trace.requests);
        }
        // Global monotonicity across the phase boundary.
        a.concat_trace().validate().unwrap();
        assert!(
            a.phases[1].trace.requests[0].time
                > a.phases[0].trace.requests.last().unwrap().time
        );
        // Different seeds move the stream.
        let mut s2 = s.clone();
        s2.seed = 6;
        let c = s2.compile(1.0).unwrap();
        assert_ne!(c.phases[0].trace.requests, a.phases[0].trace.requests);
    }

    #[test]
    fn compile_scales_phase_lengths() {
        let s = ScenarioSpec::from_toml_str(SPEC).unwrap();
        let half = s.compile(0.5).unwrap();
        assert_eq!(half.phases[0].trace.len(), 400);
        assert_eq!(half.phases[1].trace.len(), 600);
        assert!(s.compile(0.0).is_err());
    }

    #[test]
    fn csv_phase_base_loads_and_reoffsets() {
        let dir = crate::util::tempdir::TempDir::new("scn").unwrap();
        let path = dir.file("base.csv");
        let t = crate::trace::generator::netflix_like(20, 6, 300, 3);
        crate::trace::io::write_csv(&t, &path).unwrap();
        let spec = format!(
            "name = \"file\"\nn_items = 30\nn_servers = 12\n[phase]\n\
             generator = \"csv:{}\"\nrequests = 100\nrate_scale = 2.0\n",
            path.display()
        );
        let s = ScenarioSpec::from_toml_str(&spec).unwrap();
        let c = s.compile(1.0).unwrap();
        assert_eq!(c.phases[0].trace.len(), 100);
        assert_eq!(c.n_items, 30);
        c.concat_trace().validate().unwrap();
    }
}
