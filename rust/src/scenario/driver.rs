//! Phased replay drivers (DESIGN.md §7.3): run a [`CompiledScenario`]
//! end-to-end while carrying cache/ledger state across phase boundaries,
//! recording a per-phase cost breakdown.
//!
//! Two drivers with identical window semantics:
//!
//! * [`run_phased`] — in-process single-leader loop over any
//!   [`CachePolicy`] (the simulator path, works for every baseline incl.
//!   the clairvoyant OPT);
//! * [`run_phased_sharded`] — the sharded online coordinator
//!   (AKPC-only, like `akpc serve`), with per-phase cross-shard metrics
//!   deltas.
//!
//! **Phase-boundary rule:** a clique-generation window never spans a
//! phase boundary. The single-leader driver ends a (possibly partial)
//! batch at each boundary; the sharded driver mirrors it with
//! `flush_window`. Combined with the ordered/sync replay semantics of
//! DESIGN.md §2.3 this keeps the two drivers ledger-equivalent within
//! floating-point summation order — the property
//! `tests/scenario.rs::churn_storm_sharded_matches_single_leader` pins.
//!
//! **Deprecated shims** (DESIGN.md §8): both entry points now delegate
//! to the instrumented loops in [`crate::run::drive`] with no observer —
//! prefer [`crate::run::RunSpec`] for new code.

use crate::algo::CachePolicy;
use crate::cache::CostLedger;
use crate::config::AkpcConfig;
use crate::runtime::CrmEngine;
use crate::sim::ReplayMode;
use crate::util::Json;

use super::spec::CompiledScenario;

/// Cost breakdown of one phase (ledger deltas, not cumulative totals).
#[derive(Debug, Clone)]
pub struct PhaseCost {
    pub label: String,
    pub n_requests: usize,
    /// Global time window the phase covered.
    pub t_start: f64,
    pub t_end: f64,
    pub ledger: CostLedger,
}

impl PhaseCost {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("n_requests", Json::Num(self.n_requests as f64)),
            ("t_start", Json::Num(self.t_start)),
            ("t_end", Json::Num(self.t_end)),
            ("ledger", self.ledger.to_json()),
        ])
    }

    pub(crate) fn row(&self) -> String {
        format!(
            "  {:<16} reqs={:<8} total={:>12.1}  C_T={:>12.1}  C_P={:>12.1}  hit={:>5.1}%",
            self.label,
            self.n_requests,
            self.ledger.total(),
            self.ledger.c_t,
            self.ledger.c_p,
            self.ledger.hit_rate() * 100.0,
        )
    }
}

/// Outcome of one scenario replay.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    pub scenario: String,
    pub policy: String,
    /// Shard actors used; 0 = the in-process single-leader driver.
    pub n_shards: usize,
    pub phases: Vec<PhaseCost>,
    /// Whole-run ledger (the phase ledgers sum to it).
    pub total: CostLedger,
    pub wall_secs: f64,
}

impl ScenarioRun {
    /// Total cost C = C_T + C_P over the whole timeline.
    pub fn total_cost(&self) -> f64 {
        self.total.total()
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut out = format!(
            "scenario={} policy={} driver={} total={:.1} (C_T={:.1} C_P={:.1}) hit={:.1}% {:.2}s\n",
            self.scenario,
            self.policy,
            if self.n_shards == 0 {
                "single-leader".to_string()
            } else {
                format!("{}-shard", self.n_shards)
            },
            self.total.total(),
            self.total.c_t,
            self.total.c_p,
            self.total.hit_rate() * 100.0,
            self.wall_secs,
        );
        for p in &self.phases {
            out.push_str(&p.row());
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("n_shards", Json::Num(self.n_shards as f64)),
            (
                "phases",
                Json::Arr(self.phases.iter().map(PhaseCost::to_json).collect()),
            ),
            ("total", self.total.to_json()),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }
}

pub(crate) fn phase_cost(
    sc: &CompiledScenario,
    i: usize,
    cumulative: &CostLedger,
    prev: &CostLedger,
) -> PhaseCost {
    let trace = &sc.phases[i].trace;
    PhaseCost {
        label: sc.phases[i].label.clone(),
        n_requests: trace.len(),
        t_start: trace.requests.first().map(|r| r.time).unwrap_or(0.0),
        t_end: trace.requests.last().map(|r| r.time).unwrap_or(0.0),
        ledger: cumulative.delta_from(prev),
    }
}

/// Drive `policy` through the scenario with the single-leader loop,
/// snapshotting the ledger at each phase boundary.
///
/// **Deprecated shim**: delegates to [`crate::run::drive_phased`] with
/// no observer; prefer [`crate::run::RunSpec`].
pub fn run_phased(
    policy: &mut dyn CachePolicy,
    sc: &CompiledScenario,
    batch_size: usize,
) -> ScenarioRun {
    crate::run::drive_phased(policy, sc, batch_size, &mut crate::run::NullObserver)
}

/// Drive the scenario through the sharded online coordinator (AKPC), one
/// coordinator across all phases so cache/ledger state carries over.
/// `Ordered` replays the global time order from one thread (deterministic,
/// ledger-equivalent to [`run_phased`] with AKPC); `Parallel` replays each
/// shard's subsequence concurrently within every phase.
///
/// **Deprecated shim**: derives the effective cell config through
/// [`crate::run::cell_config`] (the same single derivation
/// `RunSpec::validate` uses) and delegates to
/// [`crate::run::drive_phased_sharded`], discarding the coordinator
/// metrics; prefer [`crate::run::RunSpec`], whose outcome keeps them.
pub fn run_phased_sharded(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    sc: &CompiledScenario,
    n_shards: usize,
    mode: ReplayMode,
) -> anyhow::Result<ScenarioRun> {
    let cell = crate::run::cell_config(cfg, sc.n_items, sc.n_servers);
    let (run, _metrics) = crate::run::drive_phased_sharded(
        &cell,
        engine,
        sc,
        n_shards,
        mode,
        &mut crate::run::NullObserver,
    )?;
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Akpc, NoPacking};
    use crate::scenario::spec::ScenarioSpec;

    fn small_scenario() -> CompiledScenario {
        ScenarioSpec::from_toml_str(
            r#"
            name = "drv"
            seed = 11
            n_items = 30
            n_servers = 12

            [phase]
            label = "a"
            generator = "netflix"
            requests = 900

            [phase]
            label = "b"
            generator = "netflix"
            requests = 450
            flash_frac = 0.4
            flash_items = 3
            "#,
        )
        .unwrap()
        .compile(1.0)
        .unwrap()
    }

    #[test]
    fn phases_sum_to_total_single_leader() {
        let sc = small_scenario();
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            ..Default::default()
        };
        let run = run_phased(&mut Akpc::new(&cfg), &sc, cfg.batch_size);
        assert_eq!(run.phases.len(), 2);
        assert_eq!(run.n_shards, 0);
        let req_sum: usize = run.phases.iter().map(|p| p.n_requests).sum();
        assert_eq!(req_sum, sc.total_requests());
        let cost_sum: f64 = run.phases.iter().map(|p| p.ledger.total()).sum();
        assert!(
            (cost_sum - run.total_cost()).abs() <= 1e-9 * run.total_cost().abs().max(1.0),
            "phase sum {cost_sum} != total {}",
            run.total_cost()
        );
        assert!(run.render().contains("scenario=drv"));
        crate::util::json::parse(&run.to_json().to_string()).unwrap();
    }

    #[test]
    fn phases_sum_to_total_sharded() {
        let sc = small_scenario();
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            ..Default::default()
        };
        let run = run_phased_sharded(
            &cfg,
            CrmEngine::Native,
            &sc,
            2,
            ReplayMode::Ordered,
        )
        .unwrap();
        assert_eq!(run.n_shards, 2);
        assert_eq!(run.total.requests as usize, sc.total_requests());
        let cost_sum: f64 = run.phases.iter().map(|p| p.ledger.total()).sum();
        assert!(
            (cost_sum - run.total_cost()).abs() <= 1e-9 * run.total_cost().abs().max(1.0)
        );
    }

    #[test]
    fn parallel_mode_serves_every_request() {
        let sc = small_scenario();
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            ..Default::default()
        };
        let run = run_phased_sharded(
            &cfg,
            CrmEngine::Native,
            &sc,
            3,
            ReplayMode::Parallel,
        )
        .unwrap();
        assert_eq!(run.total.requests as usize, sc.total_requests());
        assert_eq!(run.phases[0].n_requests, 900);
    }

    #[test]
    fn no_packing_runs_phased_too() {
        let sc = small_scenario();
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            ..Default::default()
        };
        let run = run_phased(&mut NoPacking::new(&cfg), &sc, cfg.batch_size);
        assert_eq!(run.policy, "NoPacking");
        assert_eq!(run.total.requests as usize, sc.total_requests());
    }
}
