//! [`ShardController`] — the volume-tracking autoscale policy.
//!
//! The controller decides, once per clique-generation window, how many
//! shards the coordinator *should* be running. It follows Carlsson &
//! Eager's observation (PAPERS.md, "Optimized Dynamic Cache
//! Instantiation") that the cloud-scale win comes from instantiating
//! capacity as request volume moves: it rides an EWMA of two demand
//! signals — per-window request *rate* and total cache *occupancy* —
//! and converts whichever is more binding into a desired shard count.
//!
//! Two classic stabilizers keep it from thrashing:
//!
//! * **hysteresis bands** — scaling up requires smoothed demand to
//!   exceed `current × scale_up_frac` shard-capacities; scaling down
//!   requires it to fall below `current × scale_down_frac`. With
//!   `scale_down_frac < scale_up_frac` there is a dead band in which
//!   the fleet holds steady.
//! * **cooldown** — after any resize the controller sits out
//!   `cooldown_windows` windows, so one spiky window cannot trigger a
//!   resize storm while the EWMA catches up.
//!
//! The controller only *recommends*; the caller (the elastic replay
//! driver or the live daemon) owns the actual `Coordinator::resize`,
//! which is why `tick` takes and returns plain shard counts.

/// Tuning knobs for the autoscaler. All fields are plain numbers so the
/// config stays `Copy` and can be embedded in
/// [`Driver::Elastic`](crate::run::Driver) without breaking its `Copy`
/// derive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerConfig {
    /// Floor on the fleet size (clamped to ≥ 1).
    pub min_shards: usize,
    /// Ceiling on the fleet size (clamped to ≥ min_shards).
    pub max_shards: usize,
    /// Request rate (requests per unit trace time) one shard handles
    /// comfortably; the rate signal divides by this.
    pub shard_capacity_rps: f64,
    /// Live cache entries one shard holds comfortably; the occupancy
    /// signal divides by this.
    pub shard_capacity_entries: f64,
    /// EWMA smoothing factor in (0, 1]; 1.0 = no smoothing (track the
    /// latest window exactly — useful for deterministic tests).
    pub ewma_alpha: f64,
    /// Scale up only when demand > current × this (in shard-capacities).
    pub scale_up_frac: f64,
    /// Scale down only when demand < current × this.
    pub scale_down_frac: f64,
    /// Windows to hold after any resize before recommending another.
    pub cooldown_windows: u32,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            min_shards: 1,
            max_shards: 8,
            shard_capacity_rps: 1_000.0,
            shard_capacity_entries: 100_000.0,
            ewma_alpha: 0.5,
            scale_up_frac: 0.9,
            scale_down_frac: 0.6,
            cooldown_windows: 2,
        }
    }
}

impl ControllerConfig {
    /// Clamp the bounds into a sane, non-empty range.
    fn sanitized(self) -> Self {
        let min_shards = self.min_shards.max(1);
        Self {
            min_shards,
            max_shards: self.max_shards.max(min_shards),
            ..self
        }
    }
}

/// The stateful controller: EWMA accumulators plus the cooldown timer.
#[derive(Debug, Clone)]
pub struct ShardController {
    cfg: ControllerConfig,
    ewma_rate: Option<f64>,
    ewma_occupancy: Option<f64>,
    cooldown: u32,
}

impl ShardController {
    pub fn new(cfg: ControllerConfig) -> Self {
        Self {
            cfg: cfg.sanitized(),
            ewma_rate: None,
            ewma_occupancy: None,
            cooldown: 0,
        }
    }

    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// Smoothed demand in shard-capacities (the max of the rate and
    /// occupancy signals), as of the last `tick`. 0.0 before any tick.
    pub fn demand(&self) -> f64 {
        let rate = self.ewma_rate.unwrap_or(0.0) / self.cfg.shard_capacity_rps.max(1e-12);
        let occ =
            self.ewma_occupancy.unwrap_or(0.0) / self.cfg.shard_capacity_entries.max(1e-12);
        rate.max(occ)
    }

    fn ewma(prev: &mut Option<f64>, sample: f64, alpha: f64) -> f64 {
        let next = match *prev {
            Some(p) => alpha * sample + (1.0 - alpha) * p,
            None => sample,
        };
        *prev = Some(next);
        next
    }

    /// Observe one closed window (`rate` = requests per unit trace
    /// time, `occupancy` = total live cache entries across the fleet)
    /// and return the recommended shard count given `current` shards.
    /// Returns `current` while inside the dead band or cooling down.
    pub fn tick(&mut self, rate: f64, occupancy: f64, current: usize) -> usize {
        let alpha = self.cfg.ewma_alpha.clamp(1e-6, 1.0);
        Self::ewma(&mut self.ewma_rate, rate, alpha);
        Self::ewma(&mut self.ewma_occupancy, occupancy, alpha);
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return current;
        }
        let demand = self.demand();
        let desired = (demand.ceil().max(1.0) as usize)
            .clamp(self.cfg.min_shards, self.cfg.max_shards);
        let current_clamped = current.clamp(self.cfg.min_shards, self.cfg.max_shards);
        let target = if desired > current_clamped
            && demand > current_clamped as f64 * self.cfg.scale_up_frac
        {
            desired
        } else if desired < current_clamped
            && demand < current_clamped as f64 * self.cfg.scale_down_frac
        {
            desired
        } else {
            current_clamped
        };
        if target != current {
            self.cooldown = self.cfg.cooldown_windows;
        }
        target
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            min_shards: 1,
            max_shards: 4,
            shard_capacity_rps: 100.0,
            shard_capacity_entries: 1e12, // occupancy signal effectively off
            ewma_alpha: 1.0,
            scale_up_frac: 1.0,
            scale_down_frac: 0.7,
            cooldown_windows: 0,
        }
    }

    #[test]
    fn scales_up_under_load_and_down_in_trough() {
        let mut c = ShardController::new(cfg());
        // Calm: demand 0.8 shards → stay at 1.
        assert_eq!(c.tick(80.0, 0.0, 1), 1);
        // Spike: demand 3.5 shards → jump to 4.
        assert_eq!(c.tick(350.0, 0.0, 1), 4);
        // Calm again: demand 0.8 < 4×0.7 → back to 1.
        assert_eq!(c.tick(80.0, 0.0, 4), 1);
    }

    #[test]
    fn dead_band_holds_steady() {
        let mut c = ShardController::new(cfg());
        // demand 1.5 with 2 shards: desired 2 == current, no move.
        assert_eq!(c.tick(150.0, 0.0, 2), 2);
        // demand 1.5 < 2×1.0 scale_up bar and > 2×0.7 scale_down bar:
        // even if desired differed, the bands would hold it.
        assert_eq!(c.tick(150.0, 0.0, 2), 2);
    }

    #[test]
    fn cooldown_suppresses_back_to_back_resizes() {
        let mut c = ShardController::new(ControllerConfig {
            cooldown_windows: 2,
            ..cfg()
        });
        assert_eq!(c.tick(350.0, 0.0, 1), 4, "first resize fires");
        // Next two windows are inside the cooldown: recommendation
        // sticks to current even though demand says shrink.
        assert_eq!(c.tick(10.0, 0.0, 4), 4);
        assert_eq!(c.tick(10.0, 0.0, 4), 4);
        // Cooldown over → the (fully-smoothed, alpha=1) trough wins.
        assert_eq!(c.tick(10.0, 0.0, 4), 1);
    }

    #[test]
    fn occupancy_signal_binds_when_rate_is_low() {
        let mut c = ShardController::new(ControllerConfig {
            shard_capacity_entries: 100.0,
            ..cfg()
        });
        // Rate says 0.1 shard, occupancy says 2.5 shards → grow to 3.
        assert_eq!(c.tick(10.0, 250.0, 1), 3);
    }

    #[test]
    fn bounds_are_respected() {
        let mut c = ShardController::new(cfg());
        assert_eq!(c.tick(1e9, 0.0, 1), 4, "capped at max_shards");
        let mut c = ShardController::new(cfg());
        assert_eq!(c.tick(0.0, 0.0, 3), 1, "floored at min_shards");
    }

    #[test]
    fn ewma_smooths_single_window_spikes() {
        let mut c = ShardController::new(ControllerConfig {
            ewma_alpha: 0.2,
            ..cfg()
        });
        // One spiky window barely moves the smoothed rate:
        // ewma = 0.2×350 = 70 → demand 0.7 → stay at 1.
        assert_eq!(c.tick(350.0, 0.0, 1), 1);
    }
}
