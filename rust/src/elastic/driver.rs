//! Elastic replay driver — controller-in-the-loop resharding over an
//! ordered trace (DESIGN.md §13.4).
//!
//! The driver serves a trace through a live [`Coordinator`] exactly
//! like the sharded replay harness, but at every clique-generation
//! window boundary it feeds the window's request rate and the fleet's
//! cache occupancy to a [`ShardController`] and, when the recommended
//! fleet size differs from the current one, performs a stateful
//! [`Coordinator::resize`]. Cache contents, cost ledgers-as-epochs,
//! clique-gen state, and the open window all carry across each resize,
//! so the merged ledger equals a never-resized run's ledger exactly —
//! what elasticity changes is only the [`RentalModel`] bill, which is
//! charged at *actual shard-seconds* of trace time per fleet-size
//! epoch plus per-window overload.
//!
//! Window boundaries are tracked by counting serves against
//! `cfg.batch_size` — the same rule the coordinator's own
//! [`WindowBatcher`](crate::coordinator::WindowBatcher) applies, and
//! the driver starts from an empty batcher, so the two stay in lockstep
//! by construction (a resize carries the open window over, keeping the
//! alignment across epochs).
//!
//! Static baselines reuse the same loop with a pinned controller
//! ([`pinned_controller`]): identical serving, identical billing, zero
//! resizes — so "elastic beats always-min and always-max" is an
//! apples-to-apples comparison on one code path.

use std::time::Instant;

use crate::config::AkpcConfig;
use crate::coordinator::{Coordinator, MetricsSnapshot, TickMode};
use crate::coordinator::ServeRequest;
use crate::runtime::CrmEngine;
use crate::trace::model::Request;
use crate::util::Json;

use super::billing::{ElasticCost, RentalModel};
use super::controller::{ControllerConfig, ShardController};

/// One fleet-size change performed by the driver.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResizeEvent {
    /// Window ordinal (1-based) whose close triggered the resize.
    pub window: u64,
    /// Trace time of the window close (= the handoff quiesce time).
    pub time: f64,
    pub from: usize,
    pub to: usize,
}

/// What an elastic (or pinned-static) replay produced.
#[derive(Debug, Clone)]
pub struct ElasticOutcome {
    /// Epoch-merged metrics: ledger/served/latency accumulate across
    /// resizes; clique-gen counters carry inside the handoffs.
    pub metrics: MetricsSnapshot,
    /// Ledger + rental + overload bill.
    pub cost: ElasticCost,
    /// Every resize, in order. Empty for pinned-static runs.
    pub resizes: Vec<ResizeEvent>,
    /// Σ shards × epoch span, in trace-time units (what rental bills).
    pub shard_seconds: f64,
    /// Fleet size when the trace ended.
    pub final_shards: usize,
    /// Largest fleet size held at any point.
    pub peak_shards: usize,
    /// Wall-clock replay duration.
    pub wall_secs: f64,
}

/// The elasticity-specific slice of an outcome — what
/// [`RunOutcome`](crate::run::RunOutcome) embeds so the unified report
/// can show the bill and the resize log without duplicating the
/// metrics snapshot.
#[derive(Debug, Clone)]
pub struct ElasticReport {
    pub cost: ElasticCost,
    pub resizes: Vec<ResizeEvent>,
    pub shard_seconds: f64,
    pub final_shards: usize,
    pub peak_shards: usize,
}

impl ElasticReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_cost", Json::Num(self.cost.total())),
            ("ledger_total", Json::Num(self.cost.ledger_total)),
            ("rental", Json::Num(self.cost.rental)),
            ("overload", Json::Num(self.cost.overload)),
            ("shard_seconds", Json::Num(self.shard_seconds)),
            ("final_shards", Json::Num(self.final_shards as f64)),
            ("peak_shards", Json::Num(self.peak_shards as f64)),
            (
                "resizes",
                Json::Arr(
                    self.resizes
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("window", Json::Num(r.window as f64)),
                                ("time", Json::Num(r.time)),
                                ("from", Json::Num(r.from as f64)),
                                ("to", Json::Num(r.to as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl ElasticOutcome {
    /// The embeddable elasticity slice (cost + resize log).
    pub fn report(&self) -> ElasticReport {
        ElasticReport {
            cost: self.cost,
            resizes: self.resizes.clone(),
            shard_seconds: self.shard_seconds,
            final_shards: self.final_shards,
            peak_shards: self.peak_shards,
        }
    }

    /// Compact one-line summary for logs and the CLI table.
    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: total={:.2} (ledger={:.2} rental={:.2} overload={:.2}) \
             shard_secs={:.2} resizes={} peak={} final={} served={}",
            self.cost.total(),
            self.cost.ledger_total,
            self.cost.rental,
            self.cost.overload,
            self.shard_seconds,
            self.resizes.len(),
            self.peak_shards,
            self.final_shards,
            self.metrics.served,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("elastic", self.report().to_json()),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// A controller pinned to exactly `n` shards — the static baseline.
/// `tick` can never leave `[n, n]`, so the driver performs no resizes.
pub fn pinned_controller(n: usize) -> ControllerConfig {
    ControllerConfig {
        min_shards: n.max(1),
        max_shards: n.max(1),
        ..ControllerConfig::default()
    }
}

/// Replay `requests` (time-ordered) through an elastic coordinator,
/// resizing at window boundaries on the controller's recommendation.
/// The fleet starts at `ctrl.min_shards`.
///
/// # Errors
///
/// Fails on an empty trace, a coordinator spawn/serve failure, or a
/// failed handoff.
pub fn drive_elastic(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    requests: &[Request],
    ctrl: ControllerConfig,
    rental: RentalModel,
) -> anyhow::Result<ElasticOutcome> {
    anyhow::ensure!(
        !requests.is_empty(),
        "elastic replay needs a non-empty trace"
    );
    let wall = Instant::now();
    let batch = cfg.batch_size.max(1);
    let mut controller = ShardController::new(ctrl);
    let mut coord = Coordinator::start_with(
        cfg.clone(),
        engine,
        controller.config().min_shards,
        TickMode::Sync,
    )?;
    let mut n_shards = coord.n_shards();
    let mut peak_shards = n_shards;

    let t_first = requests[0].time;
    // Epochs for rental: one per fleet size, closed at each resize.
    let mut epoch_start = t_first;
    let mut shard_seconds = 0.0;
    // Windows for rate + overload: closed every `batch` serves.
    let mut window_start = t_first;
    let mut in_window = 0usize;
    let mut window_no = 0u64;

    let mut priors: Vec<MetricsSnapshot> = Vec::new();
    let mut resizes: Vec<ResizeEvent> = Vec::new();
    let mut cost = ElasticCost::default();

    for r in requests {
        coord.serve(ServeRequest {
            items: r.items.clone(),
            server: r.server,
            time: Some(r.time),
        })?;
        in_window += 1;
        if in_window < batch {
            continue;
        }
        // Window closed inside the coordinator on that serve; observe it.
        window_no += 1;
        let t_end = r.time;
        let span = (t_end - window_start).max(0.0);
        cost.overload += rental.overload(n_shards, in_window, span);
        // Zero-span windows (bursts at one timestamp) read as infinite
        // rate; cap to "requests per minimum resolvable span" so the
        // EWMA saturates instead of poisoning itself with infinity.
        let rate = in_window as f64 / span.max(1e-9);
        let occupancy: f64 = coord
            .metrics()?
            .per_shard
            .iter()
            .map(|s| s.live_entries as f64)
            .sum();
        let desired = controller.tick(rate, occupancy, n_shards);
        if desired != n_shards {
            shard_seconds += n_shards as f64 * (t_end - epoch_start).max(0.0);
            let (next, retired) = coord.resize(desired)?;
            priors.push(retired.into_handoff_epoch());
            resizes.push(ResizeEvent {
                window: window_no,
                time: t_end,
                from: n_shards,
                to: desired,
            });
            coord = next;
            n_shards = desired;
            peak_shards = peak_shards.max(n_shards);
            epoch_start = t_end;
        }
        window_start = t_end;
        in_window = 0;
    }

    let t_last = requests[requests.len() - 1].time;
    if in_window > 0 {
        // Trailing partial window: bill its overload and force the tick,
        // mirroring the sharded replay harness's end-of-trace flush.
        cost.overload += rental.overload(n_shards, in_window, (t_last - window_start).max(0.0));
        coord.flush_window()?;
    }
    shard_seconds += n_shards as f64 * (t_last - epoch_start).max(0.0);
    // `shard_seconds` already carries the per-epoch shard multiplier, so
    // bill it as 1 "shard" held for that many seconds.
    cost.rental = rental.rental(1, shard_seconds);

    coord.quiesce();
    let last = coord.shutdown();
    let metrics = MetricsSnapshot::merge_epochs(&priors, last);
    cost.ledger_total = metrics.ledger.total();

    Ok(ElasticOutcome {
        metrics,
        cost,
        resizes,
        shard_seconds,
        final_shards: n_shards,
        peak_shards,
        wall_secs: wall.elapsed().as_secs_f64(),
    })
}

/// Replay with a fleet pinned at `n_shards` — the static baseline,
/// billed by the same [`RentalModel`] over the same loop.
pub fn drive_static(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    requests: &[Request],
    n_shards: usize,
    rental: RentalModel,
) -> anyhow::Result<ElasticOutcome> {
    drive_elastic(cfg, engine, requests, pinned_controller(n_shards), rental)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 8,
            batch_size: 10,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    /// Calm/spike/calm trace: `calm` windows at 10 req per time unit,
    /// `spike` windows at 400, then `calm` again. Request times are
    /// spaced so each 10-request window spans 1.0 (calm) or 0.025
    /// (spike) trace-time units.
    fn flash_crowd(calm: usize, spike: usize) -> Vec<Request> {
        let mut t = 0.0;
        let mut out = Vec::new();
        let mut push = |out: &mut Vec<Request>, t: &mut f64, windows: usize, dt: f64| {
            for i in 0..windows * 10 {
                *t += dt;
                out.push(Request::new(
                    vec![1, 2, (i % 3) as u32 + 3],
                    (i % 8) as u32,
                    *t,
                ));
            }
        };
        push(&mut out, &mut t, calm, 0.1);
        push(&mut out, &mut t, spike, 0.0025);
        push(&mut out, &mut t, calm, 0.1);
        out
    }

    fn ctrl() -> ControllerConfig {
        ControllerConfig {
            min_shards: 1,
            max_shards: 4,
            shard_capacity_rps: 20.0,
            shard_capacity_entries: 1e12,
            ewma_alpha: 1.0,
            scale_up_frac: 1.0,
            scale_down_frac: 0.7,
            cooldown_windows: 0,
        }
    }

    #[test]
    fn pinned_controller_never_resizes() {
        let reqs = flash_crowd(2, 2);
        let out = drive_static(&cfg(), CrmEngine::Native, &reqs, 2, RentalModel::default())
            .unwrap();
        assert!(out.resizes.is_empty());
        assert_eq!(out.final_shards, 2);
        assert_eq!(out.peak_shards, 2);
        assert_eq!(out.metrics.served, reqs.len() as u64);
        // Pinned fleet: shard-seconds = 2 × whole trace span.
        let span = reqs[reqs.len() - 1].time - reqs[0].time;
        assert!((out.shard_seconds - 2.0 * span).abs() < 1e-9);
    }

    #[test]
    fn elastic_scales_with_the_flash_crowd_and_back() {
        let reqs = flash_crowd(3, 3);
        let out = drive_elastic(
            &cfg(),
            CrmEngine::Native,
            &reqs,
            ctrl(),
            RentalModel::default(),
        )
        .unwrap();
        assert!(
            out.resizes.iter().any(|r| r.to > r.from),
            "spike must scale up: {:?}",
            out.resizes
        );
        assert!(
            out.resizes.iter().any(|r| r.to < r.from),
            "trough must scale back down: {:?}",
            out.resizes
        );
        assert_eq!(out.final_shards, 1, "ends calm at min_shards");
        assert!(out.peak_shards > 1);
        assert_eq!(out.metrics.served, reqs.len() as u64);
    }

    #[test]
    fn ledger_is_invariant_under_elasticity() {
        // The handoff is exact and the ledger placement-invariant, so
        // the elastic run's merged ledger must equal a static run's to
        // float round-off — only rental/overload may differ.
        let reqs = flash_crowd(2, 3);
        let elastic = drive_elastic(
            &cfg(),
            CrmEngine::Native,
            &reqs,
            ctrl(),
            RentalModel::default(),
        )
        .unwrap();
        assert!(!elastic.resizes.is_empty(), "test needs real resizes");
        let fixed =
            drive_static(&cfg(), CrmEngine::Native, &reqs, 1, RentalModel::default()).unwrap();
        let (a, b) = (elastic.cost.ledger_total, fixed.cost.ledger_total);
        assert!(
            (a - b).abs() <= 1e-9 * b.abs().max(1.0),
            "elastic ledger {a} != static ledger {b}"
        );
        assert_eq!(elastic.metrics.served, fixed.metrics.served);
        assert_eq!(elastic.metrics.windows, fixed.metrics.windows);
    }

    #[test]
    fn shard_seconds_partition_the_trace_span() {
        // Epoch spans must tile [t_first, t_last] exactly, whatever the
        // resize schedule: Σ (span × shards) ≥ span_total × min and
        // the per-epoch spans sum to the trace span.
        let reqs = flash_crowd(2, 2);
        let out = drive_elastic(
            &cfg(),
            CrmEngine::Native,
            &reqs,
            ctrl(),
            RentalModel::default(),
        )
        .unwrap();
        let span = reqs[reqs.len() - 1].time - reqs[0].time;
        // Reconstruct Σ spans from the resize log.
        let mut t_prev = reqs[0].time;
        let mut n_prev = 1usize;
        let mut expect = 0.0;
        for r in &out.resizes {
            expect += n_prev as f64 * (r.time - t_prev);
            t_prev = r.time;
            n_prev = r.to;
        }
        expect += n_prev as f64 * (reqs[reqs.len() - 1].time - t_prev);
        assert!((out.shard_seconds - expect).abs() < 1e-9);
        assert!(out.shard_seconds >= span - 1e-9, "at least 1 shard always");
    }

    #[test]
    fn empty_trace_is_rejected() {
        assert!(drive_elastic(
            &cfg(),
            CrmEngine::Native,
            &[],
            ctrl(),
            RentalModel::default()
        )
        .is_err());
    }
}
