//! Elastic shard autoscaling (DESIGN.md §13).
//!
//! The coordinator's fleet size is not fixed: this module holds the
//! pieces that let it grow and shrink mid-run with *exact* state
//! handoff — the merged cost ledger of an elastic run equals a
//! never-resized run's ledger to float round-off, so elasticity is a
//! pure infrastructure-cost play:
//!
//! * [`Placement`] — the one `server → shard` ownership rule, shared
//!   by request routing, the replay harnesses, and the handoff
//!   partitioner so they can never disagree;
//! * [`ShardController`] / [`ControllerConfig`] — the volume-tracking
//!   autoscale policy (EWMA demand, hysteresis bands, cooldown);
//! * [`RentalModel`] / [`ElasticCost`] — shard-second billing, the
//!   cost axis the ledger cannot see;
//! * [`drive_elastic`] / [`drive_static`] — the controller-in-the-loop
//!   replay driver and its pinned-fleet baseline.
//!
//! The resharding protocol itself (quiesce → export → partition →
//! resume) lives on [`Coordinator`](crate::coordinator::Coordinator)
//! (`decommission` / `resume` / `resize`); this module supplies the
//! policy and the accounting around it.

pub mod billing;
pub mod controller;
pub mod driver;
pub mod placement;

pub use billing::{ElasticCost, RentalModel};
pub use controller::{ControllerConfig, ShardController};
pub use driver::{
    drive_elastic, drive_static, pinned_controller, ElasticOutcome, ElasticReport, ResizeEvent,
};
pub use placement::Placement;
