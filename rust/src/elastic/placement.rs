//! [`Placement`] — the single `server → shard` ownership rule.
//!
//! Before this type existed the `server % N` rule was written out by
//! hand in three places (coordinator routing, `sim::replay_sharded*`'s
//! per-shard partitioning, and the scenario replay's parallel driver).
//! That duplication was harmless while N was fixed at startup; under
//! elastic resharding it becomes a correctness hazard — if routing and
//! state partitioning ever disagree about who owns a server, a resize
//! silently splits one server's cache across two shards and the
//! retention rule (Algorithm 6) loses its global view. Both the static
//! and elastic paths now go through this one type, so the handoff
//! partitioner and the request router cannot drift apart.

/// The modular placement rule: server `s` is owned by shard
/// `s mod n_shards`. Construction clamps `n_shards ≥ 1` exactly like
/// `Coordinator::start_with`, so a `Placement` is always total — every
/// server maps to some shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    n_shards: usize,
}

impl Placement {
    /// Placement over `n_shards` shards (clamped to at least 1).
    pub fn new(n_shards: usize) -> Self {
        Self {
            n_shards: n_shards.max(1),
        }
    }

    /// Number of shards this placement distributes over.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The shard that owns `server`'s cache state and serves its
    /// requests.
    pub fn shard_of(&self, server: u32) -> usize {
        server as usize % self.n_shards
    }

    /// Whether `shard` owns `server` under this placement.
    pub fn owns(&self, shard: usize, server: u32) -> bool {
        self.shard_of(server) == shard
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_total_and_modular() {
        let p = Placement::new(4);
        assert_eq!(p.n_shards(), 4);
        for server in 0..64u32 {
            let shard = p.shard_of(server);
            assert!(shard < 4);
            assert_eq!(shard, server as usize % 4);
            assert!(p.owns(shard, server));
            assert!(!p.owns((shard + 1) % 4, server));
        }
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let p = Placement::new(0);
        assert_eq!(p.n_shards(), 1);
        assert_eq!(p.shard_of(12345), 0);
    }

    #[test]
    fn single_shard_owns_everything() {
        let p = Placement::new(1);
        for server in 0..32u32 {
            assert_eq!(p.shard_of(server), 0);
        }
    }
}
