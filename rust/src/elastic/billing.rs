//! Shard-second billing — the cost axis that makes elasticity pay.
//!
//! The AKPC ledger (C_T + C_P) is *placement-invariant*: per-shard
//! ledgers sum to the single-leader total at any shard count (the PR-1
//! equivalence invariant), so shard count cannot change it and a cost
//! comparison on the ledger alone would score every fleet size the
//! same. What shard count does change is the *infrastructure* bill —
//! how many cache instances are rented, for how long, and whether the
//! fleet kept up with offered load. [`RentalModel`] prices exactly
//! that, in the spirit of Carlsson & Eager's dynamic-instantiation
//! cost (PAPERS.md):
//!
//! * **rental** — `rate_per_shard_time × Σ (shards × epoch span)`,
//!   i.e. billed at *actual shard-seconds* of trace time, not at the
//!   peak or the configured maximum;
//! * **overload** — `overload_penalty` per request beyond what the
//!   fleet could absorb in a window (`shards × shard_capacity_rps ×
//!   window span`), the SLO-miss proxy that keeps "always rent one
//!   shard" from trivially winning.
//!
//! [`ElasticCost`] folds both on top of the ledger total so elastic
//! and static runs compare on one number.

/// Infrastructure price sheet for a shard fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RentalModel {
    /// Cost of keeping one shard rented for one unit of trace time.
    pub rate_per_shard_time: f64,
    /// Requests per unit trace time one shard absorbs before requests
    /// start missing the SLO.
    pub shard_capacity_rps: f64,
    /// Cost per request beyond fleet capacity in a window.
    pub overload_penalty: f64,
}

impl Default for RentalModel {
    fn default() -> Self {
        Self {
            rate_per_shard_time: 1.0,
            shard_capacity_rps: 1_000.0,
            overload_penalty: 1.0,
        }
    }
}

impl RentalModel {
    /// Rental for `n_shards` shards held over `span` units of trace
    /// time. Negative or non-finite spans (empty epochs) bill zero.
    pub fn rental(&self, n_shards: usize, span: f64) -> f64 {
        if !span.is_finite() || span <= 0.0 {
            return 0.0;
        }
        self.rate_per_shard_time * n_shards as f64 * span
    }

    /// Overload charge for one window: `requests` offered over `span`
    /// trace-time units against `n_shards` shards of capacity.
    pub fn overload(&self, n_shards: usize, requests: usize, span: f64) -> f64 {
        if !span.is_finite() || span <= 0.0 {
            return 0.0;
        }
        let absorbed = self.shard_capacity_rps * n_shards as f64 * span;
        let excess = (requests as f64 - absorbed).max(0.0);
        self.overload_penalty * excess
    }
}

/// The three-part bill for one (elastic or static) run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ElasticCost {
    /// AKPC ledger total C = C_T + C_P (placement-invariant).
    pub ledger_total: f64,
    /// Σ rental over every fleet-size epoch, at actual shard-seconds.
    pub rental: f64,
    /// Σ per-window overload charges.
    pub overload: f64,
}

impl ElasticCost {
    /// Grand total: ledger + rental + overload.
    pub fn total(&self) -> f64 {
        self.ledger_total + self.rental + self.overload
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rental_bills_actual_shard_seconds() {
        let m = RentalModel {
            rate_per_shard_time: 2.0,
            ..Default::default()
        };
        assert!((m.rental(3, 10.0) - 60.0).abs() < 1e-12);
        assert_eq!(m.rental(3, 0.0), 0.0);
        assert_eq!(m.rental(3, f64::NEG_INFINITY), 0.0, "empty epoch");
    }

    #[test]
    fn overload_charges_only_the_excess() {
        let m = RentalModel {
            shard_capacity_rps: 10.0,
            overload_penalty: 0.5,
            ..Default::default()
        };
        // Capacity 1 shard × 10 rps × 2.0 span = 20 requests.
        assert_eq!(m.overload(1, 20, 2.0), 0.0);
        assert!((m.overload(1, 30, 2.0) - 5.0).abs() < 1e-12);
        // Double the fleet → no excess.
        assert_eq!(m.overload(2, 30, 2.0), 0.0);
    }

    #[test]
    fn cost_total_sums_all_parts() {
        let c = ElasticCost {
            ledger_total: 100.0,
            rental: 20.0,
            overload: 3.0,
        };
        assert!((c.total() - 123.0).abs() < 1e-12);
    }
}
