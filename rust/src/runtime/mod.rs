//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust hot path (never touching Python).

pub mod engine;
pub mod registry;

pub use engine::{CrmEngine, XlaCrmBuilder, XlaRuntime};
pub use registry::{ArtifactRegistry, ArtifactSpec};
