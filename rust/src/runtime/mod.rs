//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust hot path (never touching Python). The PJRT client itself requires
//! the `xla` cargo feature; without it the registry still works and the
//! engine reports itself unavailable (native fallback everywhere).

pub mod engine;
pub mod registry;

pub use engine::{CrmEngine, XlaCrmBuilder};
#[cfg(feature = "xla")]
pub use engine::XlaRuntime;
pub use registry::{ArtifactRegistry, ArtifactSpec};
