//! XLA execution engine: compiles the AOT HLO-text artifacts on the PJRT
//! CPU client once, then executes them from the coordinator's
//! clique-generation path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax ≥ 0.5
//! emits 64-bit instruction ids in serialized protos which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! The PJRT path needs the external `xla` crate, which the offline build
//! image cannot fetch, so it is gated behind the `xla` cargo feature.
//! Without it, [`XlaCrmBuilder::new`] reports the runtime unavailable and
//! every caller (CLI, coordinator, benches) falls back to the native CRM
//! engine — same decision-level outputs, pure Rust.

use crate::crm::{CrmBuilder, NativeCrmBuilder};

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;

    use crate::crm::{CrmBuilder, CrmWindow, NativeCrmBuilder};
    use crate::trace::model::Request;

    use super::super::registry::{ArtifactRegistry, ArtifactSpec};

    /// A compiled CRM executable for one `(batch, n)` artifact shape.
    struct CompiledCrm {
        exe: xla::PjRtLoadedExecutable,
        batch: usize,
        n: usize,
    }

    /// PJRT-CPU runtime holding the client and compiled executables
    /// (one per artifact shape, compiled lazily and memoized).
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        registry: ArtifactRegistry,
        compiled: HashMap<String, CompiledCrm>,
    }

    impl XlaRuntime {
        /// Create a CPU PJRT client and index the artifacts directory.
        pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
            let registry = ArtifactRegistry::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
            Ok(Self {
                client,
                registry,
                compiled: HashMap::new(),
            })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        pub fn registry(&self) -> &ArtifactRegistry {
            &self.registry
        }

        /// Can the registry serve this `(n_items, batch)` workload?
        pub fn covers(&self, n_items: usize, batch: usize) -> bool {
            self.registry.select(n_items, batch).is_some()
        }

        fn compile_spec(&mut self, spec: &ArtifactSpec) -> anyhow::Result<&CompiledCrm> {
            if !self.compiled.contains_key(&spec.file) {
                let path = self.registry.path_of(spec);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str()
                        .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
                )
                .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
                self.compiled.insert(
                    spec.file.clone(),
                    CompiledCrm {
                        exe,
                        batch: spec.batch,
                        n: spec.n,
                    },
                );
            }
            Ok(&self.compiled[&spec.file])
        }

        /// Execute the CRM pipeline on one window of requests.
        ///
        /// The incidence matrix is padded to the artifact's `(batch, n)`
        /// shape (zero rows/columns contribute nothing — verified in
        /// pytest). Windows larger than the artifact batch are folded:
        /// co-occurrence is additive over row blocks, but normalization is
        /// not, so oversized windows are rejected here and routed to the
        /// native engine by the caller.
        pub fn run_crm(
            &mut self,
            window: &[Request],
            n_items: u32,
            theta: f32,
            top_frac: f32,
        ) -> anyhow::Result<CrmWindow> {
            let spec = self
                .registry
                .select(n_items as usize, window.len())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no artifact covers n={n_items}, batch={}",
                        window.len()
                    )
                })?
                .clone();
            let compiled = self.compile_spec(&spec)?;
            let (b, n) = (compiled.batch, compiled.n);

            // Multi-hot incidence, padded.
            let mut x = vec![0.0f32; b * n];
            for (row, r) in window.iter().enumerate() {
                for &d in &r.items {
                    x[row * n + d as usize] = 1.0;
                }
            }
            let x_lit = xla::Literal::vec1(&x)
                .reshape(&[b as i64, n as i64])
                .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
            let theta_lit = xla::Literal::scalar(theta);
            let frac_lit = xla::Literal::scalar(top_frac);

            let result = compiled
                .exe
                .execute::<xla::Literal>(&[x_lit, theta_lit, frac_lit])
                .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;

            let (norm_l, bin_l, freq_l) = result
                .to_tuple3()
                .map_err(|e| anyhow::anyhow!("to_tuple3: {e:?}"))?;
            let norm: Vec<f32> = norm_l
                .to_vec()
                .map_err(|e| anyhow::anyhow!("norm to_vec: {e:?}"))?;
            let bin: Vec<f32> = bin_l
                .to_vec()
                .map_err(|e| anyhow::anyhow!("bin to_vec: {e:?}"))?;
            let freq: Vec<f32> = freq_l
                .to_vec()
                .map_err(|e| anyhow::anyhow!("freq to_vec: {e:?}"))?;

            Ok(CrmWindow::from_full(&norm, &bin, &freq, n, top_frac))
        }
    }

    /// [`CrmBuilder`] backed by the XLA runtime, with transparent native
    /// fallback for shapes no artifact covers (logged once).
    pub struct XlaCrmBuilder {
        runtime: XlaRuntime,
        native: NativeCrmBuilder,
        warned: bool,
        /// Windows served by the XLA path / the native fallback.
        pub xla_windows: u64,
        pub native_windows: u64,
    }

    impl XlaCrmBuilder {
        pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
            Ok(Self {
                runtime: XlaRuntime::new(artifacts_dir)?,
                native: NativeCrmBuilder,
                warned: false,
                xla_windows: 0,
                native_windows: 0,
            })
        }

        pub fn runtime(&self) -> &XlaRuntime {
            &self.runtime
        }
    }

    impl CrmBuilder for XlaCrmBuilder {
        fn build(
            &mut self,
            window: &[Request],
            n_items: u32,
            theta: f32,
            top_frac: f32,
        ) -> CrmWindow {
            if self.runtime.covers(n_items as usize, window.len()) {
                match self.runtime.run_crm(window, n_items, theta, top_frac) {
                    Ok(w) => {
                        self.xla_windows += 1;
                        return w;
                    }
                    Err(e) => {
                        if !self.warned {
                            eprintln!(
                                "[akpc] XLA CRM failed ({e}); falling back to native"
                            );
                            self.warned = true;
                        }
                    }
                }
            } else if !self.warned {
                eprintln!(
                    "[akpc] no artifact covers n={n_items}, batch={} — native CRM engine",
                    window.len()
                );
                self.warned = true;
            }
            self.native_windows += 1;
            self.native.build(window, n_items, theta, top_frac)
        }

        fn engine_name(&self) -> &'static str {
            "xla"
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{XlaCrmBuilder, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::crm::{CrmBuilder, CrmWindow, NativeCrmBuilder};
    use crate::trace::model::Request;

    /// Feature-gated stand-in: constructing it always fails, so callers
    /// take their existing native-fallback paths. Kept as a real type so
    /// code and tests referencing `XlaCrmBuilder` compile unchanged.
    pub struct XlaCrmBuilder {
        native: NativeCrmBuilder,
        /// Mirror the real builder's counters for API parity.
        pub xla_windows: u64,
        pub native_windows: u64,
    }

    impl XlaCrmBuilder {
        pub fn new(_artifacts_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
            anyhow::bail!(
                "akpc was built without the `xla` feature; PJRT runtime unavailable"
            )
        }
    }

    impl CrmBuilder for XlaCrmBuilder {
        fn build(
            &mut self,
            window: &[Request],
            n_items: u32,
            theta: f32,
            top_frac: f32,
        ) -> CrmWindow {
            self.native_windows += 1;
            self.native.build(window, n_items, theta, top_frac)
        }

        fn engine_name(&self) -> &'static str {
            "xla"
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaCrmBuilder;

/// Engine selection for the CLI / experiments. `Copy` so coordinators
/// can remember their engine choice across elastic resizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrmEngine {
    Native,
    Xla,
}

impl CrmEngine {
    /// Instantiate a boxed builder; `Xla` falls back to native (with a
    /// warning) when artifacts — or the `xla` feature — are absent.
    pub fn builder(&self, artifacts_dir: &str) -> Box<dyn CrmBuilder> {
        match self {
            CrmEngine::Native => Box::new(NativeCrmBuilder),
            CrmEngine::Xla => match XlaCrmBuilder::new(artifacts_dir) {
                Ok(b) => Box::new(b),
                Err(e) => {
                    eprintln!("[akpc] XLA runtime unavailable ({e}); using native CRM");
                    Box::new(NativeCrmBuilder)
                }
            },
        }
    }
}
