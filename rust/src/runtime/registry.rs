//! Artifact registry: discovers the AOT artifacts `make artifacts`
//! produced (`artifacts/manifest.json` + `crm_b*_n*.hlo.txt`) and selects
//! the smallest compiled shape covering a requested workload size.

use std::path::{Path, PathBuf};

use crate::util::json;

/// One artifact entry from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub batch: usize,
    pub n: usize,
}

/// The set of available AOT artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
}

impl ArtifactRegistry {
    /// Load from an artifacts directory; errors if the manifest is missing
    /// (run `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            )
        })?;
        let doc = json::parse(&text)?;
        let mut specs: Vec<ArtifactSpec> = doc
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing `artifacts`"))?
            .iter()
            .map(|e| -> anyhow::Result<ArtifactSpec> {
                Ok(ArtifactSpec {
                    file: e
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow::anyhow!("artifact missing `file`"))?
                        .to_string(),
                    batch: e
                        .get("batch")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| anyhow::anyhow!("artifact missing `batch`"))?,
                    n: e
                        .get("n")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| anyhow::anyhow!("artifact missing `n`"))?,
                })
            })
            .collect::<anyhow::Result<_>>()?;
        specs.sort_by_key(|s| (s.n, s.batch));
        anyhow::ensure!(!specs.is_empty(), "manifest lists no artifacts");
        Ok(Self { dir, specs })
    }

    /// All specs, ascending by `(n, batch)`.
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Smallest artifact with `n >= n_items` and `batch >= batch_size`
    /// (inputs are padded up to the artifact shape).
    pub fn select(&self, n_items: usize, batch_size: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.n >= n_items && s.batch >= batch_size)
            .min_by_key(|s| (s.n, s.batch))
    }

    /// Absolute path of a spec's HLO text file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn fake_registry() -> (TempDir, ArtifactRegistry) {
        let dir = TempDir::new("registry").unwrap();
        let manifest = r#"{"artifacts": [
                {"file": "crm_b256_n64.hlo.txt", "batch": 256, "n": 64},
                {"file": "crm_b256_n128.hlo.txt", "batch": 256, "n": 128},
                {"file": "crm_b512_n512.hlo.txt", "batch": 512, "n": 512}
        ]}"#;
        std::fs::write(dir.file("manifest.json"), manifest).unwrap();
        let reg = ArtifactRegistry::load(dir.path()).unwrap();
        (dir, reg)
    }

    #[test]
    fn selects_smallest_covering() {
        let (_d, reg) = fake_registry();
        assert_eq!(reg.select(60, 200).unwrap().n, 64);
        assert_eq!(reg.select(65, 200).unwrap().n, 128);
        assert_eq!(reg.select(128, 200).unwrap().n, 128);
        assert_eq!(reg.select(300, 500).unwrap().n, 512);
    }

    #[test]
    fn none_when_too_large() {
        let (_d, reg) = fake_registry();
        assert!(reg.select(2048, 200).is_none());
        assert!(reg.select(60, 1024).is_none());
    }

    #[test]
    fn missing_manifest_is_error() {
        let dir = TempDir::new("empty").unwrap();
        let err = ArtifactRegistry::load(dir.path()).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
