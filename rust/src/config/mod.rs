//! Configuration system.
//!
//! [`AkpcConfig`] carries every tunable in the paper (Table II defaults),
//! loadable from TOML ([`toml_lite`] — the environment is offline, so the
//! parser is in-tree) and overridable from the CLI. Experiment sweeps
//! (Figs. 6-8) are expressed as transformations over a base config.

pub mod toml_lite;

use std::path::Path;

/// How the caching cost is attributed (see DESIGN.md §6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChargePolicy {
    /// Paper-faithful (Eq. 1 / Alg. 5 line 5 / Thm. 1): caching cost is
    /// charged per *requested* item whose clique's expiry is set/extended.
    #[default]
    RequestedItems,
    /// Physical accounting: charge every item of the cached clique.
    CliqueItems,
}

impl ChargePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            ChargePolicy::RequestedItems => "requested_items",
            ChargePolicy::CliqueItems => "clique_items",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "requested_items" => Ok(Self::RequestedItems),
            "clique_items" => Ok(Self::CliqueItems),
            _ => anyhow::bail!("unknown charge_policy `{s}`"),
        }
    }
}

/// Which packed-transfer cost formula to use (paper inconsistency,
/// DESIGN.md §6): Eq. 3 `(1+(|c|-1)α)λ` (default) vs Alg. 5 line 12
/// `α·μ·|c|`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransferModel {
    #[default]
    Eq3,
    Alg5Line12,
}

impl TransferModel {
    pub fn as_str(&self) -> &'static str {
        match self {
            TransferModel::Eq3 => "eq3",
            TransferModel::Alg5Line12 => "alg5_line12",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "eq3" => Ok(Self::Eq3),
            "alg5_line12" => Ok(Self::Alg5Line12),
            _ => anyhow::bail!("unknown transfer_model `{s}`"),
        }
    }
}

/// Full system configuration. Defaults reproduce the paper's Table II.
#[derive(Debug, Clone, PartialEq)]
pub struct AkpcConfig {
    // ---- cost model (Table I / II) ----
    /// Caching cost per data item per unit time (μ).
    pub mu: f64,
    /// Base transfer cost per data item (λ).
    pub lambda: f64,
    /// Cost-ratio constant ρ; the expiry window is Δt = ρ·λ/μ (Alg. 6 l.1).
    pub rho: f64,
    /// Packed-transfer discount factor α ∈ [0, 1].
    pub alpha: f64,

    // ---- clique generation (Alg. 2-4) ----
    /// Maximum (and target) clique size ω.
    pub omega: u32,
    /// CRM binarization threshold θ.
    pub theta: f32,
    /// Approximate-clique-merging density threshold γ.
    pub gamma_approx: f32,
    /// Fraction of most-frequent active items kept in the CRM.
    /// Default 1.0: the paper's "top 10% of the dataset" extraction
    /// (§V-A) happens at *dataset construction* — Table II's n = 60 is
    /// already the post-filter universe, so the CRM covers all n items.
    /// Lower values re-enable the filter for large-n runs (Fig. 9b).
    pub crm_top_frac: f32,
    /// CRM construction window: number of most-recent batches whose
    /// requests feed Algorithm 2 (the clique-generation *period* T^CG is
    /// one batch; the correlation *window* W spans this many batches —
    /// Fig. 3 separates the two).
    pub crm_window_batches: usize,
    /// Co-utilization session gap, as a fraction of Δt: consecutive
    /// requests at one server merge into one CRM transaction when their
    /// inter-arrival gap is below `session_gap_frac · Δt`. Must be well
    /// below 1.0, or independent sessions at hot servers chain into
    /// cross-bundle transactions and poison the CRM.
    pub session_gap_frac: f64,
    /// Enable Clique Splitting (CS).
    pub clique_splitting: bool,
    /// Enable Approximate Clique Merging (ACM).
    pub approx_merging: bool,

    // ---- workload / system shape (Table II) ----
    /// Number of edge storage servers m = |S|.
    pub n_servers: u32,
    /// Number of data items n = |U|.
    pub n_items: u32,
    /// Requests per batch; the clique-generation window T^CG is one batch.
    pub batch_size: usize,
    /// Maximum request size d_max.
    pub d_max: usize,

    // ---- accounting variants ----
    pub charge_policy: ChargePolicy,
    pub transfer_model: TransferModel,

    // ---- runtime ----
    /// Directory holding AOT artifacts (`crm_b*_n*.hlo.txt` + manifest).
    pub artifacts_dir: String,
    /// Prefer the XLA engine when an artifact covers `n_items`.
    pub use_xla: bool,

    /// RNG seed for everything derived from this config.
    pub seed: u64,
}

impl Default for AkpcConfig {
    fn default() -> Self {
        Self {
            mu: 1.0,
            lambda: 1.0,
            rho: 1.0,
            alpha: 0.8,
            omega: 5,
            theta: 0.2,
            gamma_approx: 0.85,
            crm_top_frac: 1.0,
            crm_window_batches: 10,
            session_gap_frac: 0.05,
            clique_splitting: true,
            approx_merging: true,
            n_servers: 600,
            n_items: 60,
            batch_size: 200,
            d_max: 5,
            charge_policy: ChargePolicy::default(),
            transfer_model: TransferModel::default(),
            artifacts_dir: "artifacts".to_string(),
            use_xla: true,
            seed: 0xAC_2025,
        }
    }
}

impl AkpcConfig {
    /// The cache-expiry window Δt = ρ·λ/μ (Algorithm 6 line 1).
    pub fn delta_t(&self) -> f64 {
        self.rho * self.lambda / self.mu
    }

    /// Parse from TOML text; unknown keys are rejected, missing keys keep
    /// defaults.
    pub fn from_toml_str(text: &str) -> anyhow::Result<Self> {
        let map = toml_lite::parse(text)?;
        let mut cfg = Self::default();
        cfg.apply_toml_map(&map)?;
        Ok(cfg)
    }

    /// Apply a parsed key/value table onto this config. Shared by
    /// [`from_toml_str`](Self::from_toml_str) and embedders that carry an
    /// `[akpc]` sub-table inside their own TOML document (the serving
    /// daemon's `ServeConfig`, DESIGN.md §12.3): both get the same key
    /// set, the same coercions, and the same unknown-key rejection.
    pub fn apply_toml_map(
        &mut self,
        map: &std::collections::BTreeMap<String, toml_lite::Value>,
    ) -> anyhow::Result<()> {
        let cfg = self;
        for (k, v) in map {
            let num = || {
                v.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("`{k}` must be a number"))
            };
            let flag = || {
                v.as_bool()
                    .ok_or_else(|| anyhow::anyhow!("`{k}` must be a bool"))
            };
            let text = || {
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("`{k}` must be a string"))
            };
            match k.as_str() {
                "mu" => cfg.mu = num()?,
                "lambda" => cfg.lambda = num()?,
                "rho" => cfg.rho = num()?,
                "alpha" => cfg.alpha = num()?,
                "omega" => cfg.omega = num()? as u32,
                "theta" => cfg.theta = num()? as f32,
                "gamma_approx" => cfg.gamma_approx = num()? as f32,
                "crm_top_frac" => cfg.crm_top_frac = num()? as f32,
                "crm_window_batches" => cfg.crm_window_batches = num()? as usize,
                "session_gap_frac" => cfg.session_gap_frac = num()?,
                "clique_splitting" => cfg.clique_splitting = flag()?,
                "approx_merging" => cfg.approx_merging = flag()?,
                "n_servers" => cfg.n_servers = num()? as u32,
                "n_items" => cfg.n_items = num()? as u32,
                "batch_size" => cfg.batch_size = num()? as usize,
                "d_max" => cfg.d_max = num()? as usize,
                "charge_policy" => cfg.charge_policy = ChargePolicy::parse(text()?)?,
                "transfer_model" => cfg.transfer_model = TransferModel::parse(text()?)?,
                "artifacts_dir" => cfg.artifacts_dir = text()?.to_string(),
                "use_xla" => cfg.use_xla = flag()?,
                "seed" => cfg.seed = num()? as u64,
                _ => anyhow::bail!("unknown config key `{k}`"),
            }
        }
        Ok(())
    }

    /// Load from a TOML file.
    pub fn from_toml_file(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        Self::from_toml_str(&std::fs::read_to_string(path.as_ref())?)
    }

    /// Serialize to TOML.
    pub fn to_toml(&self) -> String {
        format!(
            "# AKPC configuration (defaults = paper Table II)\n\
             mu = {}\nlambda = {}\nrho = {}\nalpha = {}\n\
             omega = {}\ntheta = {}\ngamma_approx = {}\ncrm_top_frac = {}\n\
             crm_window_batches = {}\nsession_gap_frac = {}\n\
             clique_splitting = {}\napprox_merging = {}\n\
             n_servers = {}\nn_items = {}\nbatch_size = {}\nd_max = {}\n\
             charge_policy = {}\ntransfer_model = {}\n\
             artifacts_dir = {}\nuse_xla = {}\nseed = {}\n",
            self.mu,
            self.lambda,
            self.rho,
            self.alpha,
            self.omega,
            self.theta,
            self.gamma_approx,
            self.crm_top_frac,
            self.crm_window_batches,
            self.session_gap_frac,
            self.clique_splitting,
            self.approx_merging,
            self.n_servers,
            self.n_items,
            self.batch_size,
            self.d_max,
            toml_lite::quote(self.charge_policy.as_str()),
            toml_lite::quote(self.transfer_model.as_str()),
            toml_lite::quote(&self.artifacts_dir),
            self.use_xla,
            self.seed,
        )
    }

    /// Validate invariants; called by the CLI and the simulator.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.mu > 0.0, "mu must be positive");
        anyhow::ensure!(self.lambda > 0.0, "lambda must be positive");
        anyhow::ensure!(self.rho > 0.0, "rho must be positive");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.alpha),
            "alpha must be in [0,1]"
        );
        anyhow::ensure!(self.omega >= 1, "omega must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.theta),
            "theta must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.gamma_approx),
            "gamma_approx must be in [0,1]"
        );
        anyhow::ensure!(
            self.crm_top_frac > 0.0 && self.crm_top_frac <= 1.0,
            "crm_top_frac must be in (0,1]"
        );
        anyhow::ensure!(self.n_servers >= 1, "need at least one server");
        anyhow::ensure!(self.n_items >= 1, "need at least one item");
        anyhow::ensure!(self.batch_size >= 1, "batch_size must be >= 1");
        anyhow::ensure!(self.crm_window_batches >= 1, "crm_window_batches must be >= 1");
        anyhow::ensure!(
            self.session_gap_frac > 0.0,
            "session_gap_frac must be positive"
        );
        anyhow::ensure!(self.d_max >= 1, "d_max must be >= 1");
        Ok(())
    }

    /// AKPC variant without clique splitting and approximate merging
    /// ("AKPC w/o CS, w/o ACM" in Figs. 5, 7, 9).
    pub fn without_cs_acm(&self) -> Self {
        Self {
            clique_splitting: false,
            approx_merging: false,
            ..self.clone()
        }
    }

    /// AKPC variant with splitting only ("AKPC w/o ACM" in Fig. 9a).
    pub fn without_acm(&self) -> Self {
        Self {
            approx_merging: false,
            ..self.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_defaults() {
        let c = AkpcConfig::default();
        assert_eq!(c.mu, 1.0);
        assert_eq!(c.lambda, 1.0);
        assert_eq!(c.rho, 1.0);
        assert_eq!(c.alpha, 0.8);
        assert_eq!(c.omega, 5);
        assert_eq!(c.theta, 0.2);
        assert_eq!(c.gamma_approx, 0.85);
        assert_eq!(c.n_servers, 600);
        assert_eq!(c.n_items, 60);
        assert_eq!(c.batch_size, 200);
        assert_eq!(c.d_max, 5);
        assert!((c.crm_top_frac - 1.0).abs() < 1e-9);
        c.validate().unwrap();
    }

    #[test]
    fn delta_t_follows_rho() {
        let mut c = AkpcConfig::default();
        assert_eq!(c.delta_t(), 1.0);
        c.rho = 4.0;
        assert_eq!(c.delta_t(), 4.0);
        c.mu = 2.0;
        assert_eq!(c.delta_t(), 2.0);
    }

    #[test]
    fn toml_roundtrip() {
        let c = AkpcConfig {
            alpha: 0.6,
            omega: 7,
            charge_policy: ChargePolicy::CliqueItems,
            artifacts_dir: "my/arts".into(),
            ..Default::default()
        };
        let text = c.to_toml();
        let back = AkpcConfig::from_toml_str(&text).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let back = AkpcConfig::from_toml_str("alpha = 0.5").unwrap();
        assert_eq!(back.alpha, 0.5);
        assert_eq!(back.omega, 5); // default
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(AkpcConfig::from_toml_str("nonsense = 1").is_err());
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = AkpcConfig::default();
        c.alpha = 1.5;
        assert!(c.validate().is_err());
        c = AkpcConfig::default();
        c.mu = 0.0;
        assert!(c.validate().is_err());
        c = AkpcConfig::default();
        c.omega = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn variants_flip_flags() {
        let c = AkpcConfig::default();
        let v = c.without_cs_acm();
        assert!(!v.clique_splitting && !v.approx_merging);
        let v = c.without_acm();
        assert!(v.clique_splitting && !v.approx_merging);
    }

    #[test]
    fn enum_parsers() {
        assert_eq!(
            ChargePolicy::parse("clique_items").unwrap(),
            ChargePolicy::CliqueItems
        );
        assert!(ChargePolicy::parse("bogus").is_err());
        assert_eq!(
            TransferModel::parse("alg5_line12").unwrap(),
            TransferModel::Alg5Line12
        );
    }
}
