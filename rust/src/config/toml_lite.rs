//! TOML-subset reader (offline environment — no `toml` crate): flat
//! `key = value` documents with `#` comments; values are strings, bools,
//! integers or floats. Exactly what [`super::AkpcConfig`] needs.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Num(f64),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse a flat TOML document into key → value.
pub fn parse(text: &str) -> anyhow::Result<BTreeMap<String, Value>> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() || line.starts_with('[') {
            // Tables are ignored (config is flat).
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim().to_string();
        let val = v.trim();
        let value = if let Some(stripped) = val.strip_prefix('"') {
            let inner = stripped
                .strip_suffix('"')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated string", lineno + 1))?;
            Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\"))
        } else if val == "true" {
            Value::Bool(true)
        } else if val == "false" {
            Value::Bool(false)
        } else {
            Value::Num(
                val.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("line {}: bad value `{val}`", lineno + 1))?,
            )
        };
        map.insert(key, value);
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Render a string value with escaping.
pub fn quote(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_document() {
        let doc = r#"
            # costs
            mu = 1.0
            omega = 5
            use_xla = true
            artifacts_dir = "artifacts"  # trailing comment
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["mu"].as_f64(), Some(1.0));
        assert_eq!(m["omega"].as_f64(), Some(5.0));
        assert_eq!(m["use_xla"].as_bool(), Some(true));
        assert_eq!(m["artifacts_dir"].as_str(), Some("artifacts"));
    }

    #[test]
    fn hash_inside_string_kept() {
        let m = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(m["name"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("x = notanumber").is_err());
    }

    #[test]
    fn quote_roundtrip() {
        let q = quote("a\"b\\c");
        let m = parse(&format!("k = {q}")).unwrap();
        assert_eq!(m["k"].as_str(), Some("a\"b\\c"));
    }

    #[test]
    fn ignores_tables() {
        let m = parse("[section]\nx = 1").unwrap();
        assert_eq!(m["x"].as_f64(), Some(1.0));
    }
}
