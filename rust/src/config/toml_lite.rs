//! TOML-subset reader (offline environment — no `toml` crate):
//! `key = value` documents with `#` comments and optional `[table]`
//! sections; values are strings, bools, integers or floats. [`parse`]
//! flattens tables (what [`super::AkpcConfig`] needs); [`parse_doc`]
//! keeps them, in document order, so repeated sections can express
//! ordered lists — the scenario spec grammar (`[[phase]]`-style, written
//! as repeated `[phase]` blocks) is built on it (DESIGN.md §7).

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Num(f64),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// A parsed document that keeps `[table]` structure: the keys before the
/// first table header, plus every table block in document order. The same
/// table name may repeat — each block is a separate entry, which is how
/// ordered lists (scenario phases) are expressed in this subset.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub root: BTreeMap<String, Value>,
    pub tables: Vec<(String, BTreeMap<String, Value>)>,
}

/// Parse a document preserving `[table]` sections.
pub fn parse_doc(text: &str) -> anyhow::Result<Doc> {
    let mut doc = Doc::default();
    // None = still in the root block; Some(i) = filling tables[i].
    let mut current: Option<usize> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated table header", lineno + 1))?
                .trim();
            anyhow::ensure!(!name.is_empty(), "line {}: empty table name", lineno + 1);
            doc.tables.push((name.to_string(), BTreeMap::new()));
            current = Some(doc.tables.len() - 1);
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim().to_string();
        let val = v.trim();
        let value = if let Some(stripped) = val.strip_prefix('"') {
            let inner = stripped
                .strip_suffix('"')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated string", lineno + 1))?;
            Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\"))
        } else if val == "true" {
            Value::Bool(true)
        } else if val == "false" {
            Value::Bool(false)
        } else {
            Value::Num(
                val.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("line {}: bad value `{val}`", lineno + 1))?,
            )
        };
        match current {
            None => doc.root.insert(key, value),
            Some(i) => doc.tables[i].1.insert(key, value),
        };
    }
    Ok(doc)
}

/// Parse a flat TOML document into key → value (tables are flattened into
/// the root map, later keys winning — the historical behavior flat-config
/// callers rely on).
pub fn parse(text: &str) -> anyhow::Result<BTreeMap<String, Value>> {
    let doc = parse_doc(text)?;
    let mut map = doc.root;
    for (_, table) in doc.tables {
        map.extend(table);
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Render a string value with escaping.
pub fn quote(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_document() {
        let doc = r#"
            # costs
            mu = 1.0
            omega = 5
            use_xla = true
            artifacts_dir = "artifacts"  # trailing comment
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["mu"].as_f64(), Some(1.0));
        assert_eq!(m["omega"].as_f64(), Some(5.0));
        assert_eq!(m["use_xla"].as_bool(), Some(true));
        assert_eq!(m["artifacts_dir"].as_str(), Some("artifacts"));
    }

    #[test]
    fn hash_inside_string_kept() {
        let m = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(m["name"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("x = notanumber").is_err());
    }

    #[test]
    fn quote_roundtrip() {
        let q = quote("a\"b\\c");
        let m = parse(&format!("k = {q}")).unwrap();
        assert_eq!(m["k"].as_str(), Some("a\"b\\c"));
    }

    #[test]
    fn ignores_tables() {
        let m = parse("[section]\nx = 1").unwrap();
        assert_eq!(m["x"].as_f64(), Some(1.0));
    }

    #[test]
    fn parse_doc_keeps_repeated_tables_in_order() {
        let doc = parse_doc(
            "name = \"s\"\n[phase]\nlabel = \"a\"\nrequests = 10\n\
             [phase]\nlabel = \"b\"\nrequests = 20\n",
        )
        .unwrap();
        assert_eq!(doc.root["name"].as_str(), Some("s"));
        assert_eq!(doc.tables.len(), 2);
        assert_eq!(doc.tables[0].0, "phase");
        assert_eq!(doc.tables[0].1["label"].as_str(), Some("a"));
        assert_eq!(doc.tables[1].1["requests"].as_f64(), Some(20.0));
    }

    #[test]
    fn parse_doc_rejects_bad_headers() {
        assert!(parse_doc("[unterminated\nx = 1").is_err());
        assert!(parse_doc("[]\nx = 1").is_err());
    }
}
