//! Small deterministic utilities shared across the crate.
//!
//! Everything here is dependency-free and fully deterministic so that every
//! experiment in the paper harness is exactly reproducible from a seed.

pub mod benchkit;
pub mod hist;
pub mod json;
pub mod order;
pub mod rng;
pub mod tempdir;

pub use hist::Histogram;
pub use json::Json;
pub use rng::{Rng, ZipfSampler};

/// Min-max normalize a slice in place; returns `(min, max)` before scaling.
/// A constant slice maps to all zeros (span clamped like the L2 graph).
pub fn min_max_normalize(values: &mut [f32]) -> (f32, f32) {
    if values.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in values.iter() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-9);
    for v in values.iter_mut() {
        *v = (*v - lo) / span;
    }
    (lo, hi)
}

/// Stable content hash for a *sorted* item set — the cache key for a packed
/// clique copy. FNV-1a over the little-endian item ids; collision
/// probability is negligible at the paper's scales and the key is only used
/// to identify identical packings.
pub fn clique_key(sorted_items: &[u32]) -> u64 {
    debug_assert!(sorted_items.windows(2).all(|w| w[0] < w[1]));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &d in sorted_items {
        for b in d.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_basic() {
        let mut v = vec![2.0, 4.0, 6.0];
        let (lo, hi) = min_max_normalize(&mut v);
        assert_eq!((lo, hi), (2.0, 6.0));
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn normalize_constant_is_zero() {
        let mut v = vec![3.0; 4];
        min_max_normalize(&mut v);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn normalize_empty() {
        let mut v: Vec<f32> = vec![];
        assert_eq!(min_max_normalize(&mut v), (0.0, 0.0));
    }

    #[test]
    fn clique_key_distinguishes_sets() {
        assert_ne!(clique_key(&[1, 2, 3]), clique_key(&[1, 2, 4]));
        assert_ne!(clique_key(&[1]), clique_key(&[2]));
        assert_ne!(clique_key(&[1, 2]), clique_key(&[12]));
        assert_eq!(clique_key(&[5, 9]), clique_key(&[5, 9]));
    }
}
