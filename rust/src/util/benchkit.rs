//! Tiny benchmarking harness used by `cargo bench` (the offline
//! environment has no criterion). Warms up, runs timed iterations, and
//! prints min/median/mean per benchmark in a stable, greppable format:
//!
//! ```text
//! bench <group>/<name> ... min=1.234ms med=1.301ms mean=1.310ms iters=20
//! ```

use std::time::{Duration, Instant};

/// One benchmark group (criterion-style naming).
pub struct Group {
    name: String,
    /// Target measured iterations per benchmark.
    pub iters: usize,
    /// Warm-up iterations.
    pub warmup: usize,
}

impl Group {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            iters: 10,
            warmup: 2,
        }
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n.max(1);
        self
    }

    /// Run one benchmark; `f` returns any value (kept alive to prevent
    /// dead-code elimination via `std::hint::black_box`).
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let stats = BenchStats::from_samples(&self.name, name, samples);
        println!("{stats}");
        stats
    }
}

/// Summary of one benchmark run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub group: String,
    pub name: String,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub iters: usize,
}

impl BenchStats {
    fn from_samples(group: &str, name: &str, mut samples: Vec<Duration>) -> Self {
        samples.sort_unstable();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        Self {
            group: group.to_string(),
            name: name.to_string(),
            min: samples[0],
            median: samples[n / 2],
            mean,
            iters: n,
        }
    }

    /// Median seconds (for derived throughput reporting).
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bench {}/{} ... min={} med={} mean={} iters={}",
            self.group,
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let g = Group::new("test").iters(5);
        let stats = g.bench("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(stats.min <= stats.median && stats.median <= stats.mean * 2);
        assert_eq!(stats.iters, 5);
    }

    #[test]
    fn formats_durations() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("us"));
    }
}
