//! Total-order float comparators — the only sanctioned way to sort or
//! select on `f32`/`f64` keys in this crate.
//!
//! Every equivalence claim the test suite pins (sharded == single-leader,
//! sparse == dense oracle, streamed == materialized) assumes that float
//! orderings are *total*: `partial_cmp(..).unwrap()` panics on NaN, and
//! `unwrap_or(Equal)` silently violates strict weak ordering, which
//! `sort_by` is allowed to answer with an arbitrary permutation (or a
//! panic). Both failure modes have bitten this repo before (the PR-1
//! `ExpEvent` heap order, the PR-4 merge ties), so `akpc-lint` rule L1
//! (DESIGN.md §11) bans them outright and points here.
//!
//! The comparators wrap [`f64::total_cmp`]/[`f32::total_cmp`] (IEEE 754
//! `totalOrder`): NaN sorts above +∞ (and `-NaN` below −∞) instead of
//! poisoning the comparison, `-0.0 < +0.0`, and the order is consistent
//! for every input pair. Function-pointer-shaped so they drop straight
//! into `sort_by`/`binary_search_by`/`select_nth_unstable_by`:
//!
//! ```
//! use akpc::util::order;
//!
//! let mut xs = vec![2.0f64, f64::NAN, 1.0];
//! xs.sort_by(order::total_f64);            // no panic: [1.0, 2.0, NaN]
//! assert_eq!(xs[0], 1.0);
//! let mut ys = vec![0.5f32, 2.5, 1.5];
//! ys.sort_by(order::desc_f32);             // descending: [2.5, 1.5, 0.5]
//! assert_eq!(ys[0], 2.5);
//! ```

use std::cmp::Ordering;

/// Ascending total order on `f64` (`a` before `b` when `a < b`).
#[inline]
pub fn total_f64(a: &f64, b: &f64) -> Ordering {
    a.total_cmp(b)
}

/// Ascending total order on `f32`.
#[inline]
pub fn total_f32(a: &f32, b: &f32) -> Ordering {
    a.total_cmp(b)
}

/// Descending total order on `f64` (largest first).
#[inline]
pub fn desc_f64(a: &f64, b: &f64) -> Ordering {
    b.total_cmp(a)
}

/// Descending total order on `f32` (largest first).
#[inline]
pub fn desc_f32(a: &f32, b: &f32) -> Ordering {
    b.total_cmp(a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_never_panics_and_sorts_last_ascending() {
        let mut xs = vec![3.0f64, f64::NAN, 1.0, 2.0];
        xs.sort_by(total_f64);
        assert_eq!(&xs[..3], &[1.0, 2.0, 3.0]);
        assert!(xs[3].is_nan());
    }

    #[test]
    fn descending_is_reverse_of_ascending() {
        let mut up = vec![0.25f32, -1.5, 7.0, 0.0];
        let mut down = up.clone();
        up.sort_by(total_f32);
        down.sort_by(desc_f32);
        up.reverse();
        assert_eq!(up, down);
    }

    #[test]
    fn total_order_is_antisymmetric_on_zeros() {
        // total_cmp distinguishes -0.0 from +0.0 — consistently.
        assert_eq!(total_f64(&-0.0, &0.0), Ordering::Less);
        assert_eq!(desc_f64(&-0.0, &0.0), Ordering::Greater);
    }

    #[test]
    fn binary_search_with_nan_table_terminates() {
        // A degenerate table (all NaN) still yields a well-defined
        // insertion point instead of panicking mid-search.
        let cdf = vec![f64::NAN; 5];
        let r = cdf.binary_search_by(|p| p.total_cmp(&0.5));
        assert!(matches!(r, Err(0)), "NaN > every finite in total order");
    }
}
