//! Minimal JSON parser + emitter (the build environment is offline, so
//! serde_json is unavailable — DESIGN.md §2). Supports the full JSON value
//! grammar; used for the artifact manifest, reports, and metrics export.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * level),
                " ".repeat(w * (level + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, level + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected '{}' at {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(s.as_bytes()),
            "bad literal at {}",
            self.pos
        );
        self.pos += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut arr = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    arr.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(arr));
                        }
                        _ => anyhow::bail!("expected ',' or ']' at {}", self.pos),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    map.insert(k, self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => anyhow::bail!("expected ',' or '}}' at {}", self.pos),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let start = self.pos;
                self.pos += 1;
                while matches!(
                    self.peek(),
                    Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                ) {
                    self.pos += 1;
                }
                let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
                Ok(Json::Num(s.parse()?))
            }
            _ => anyhow::bail!("unexpected byte at {}", self.pos),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => anyhow::bail!("bad escape at {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"artifacts":[{"file":"a.hlo.txt","batch":256,"n":64}],"ok":true}"#;
        let v = parse(text).unwrap();
        let arr = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("n").unwrap().as_usize(), Some(64));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn parses_nested_and_ws() {
        let v = parse(" { \"a\" : [ 1 , 2.5 , -3e2 ] , \"b\" : null } ").unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64(), Some(2.5));
        assert_eq!(a[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("line\n\"quote\"\\tab\t".into());
        let back = parse(&original.to_string()).unwrap();
        assert_eq!(back, original);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn pretty_print_is_parseable() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Bool(false), Json::Str("s".into())])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(4.25).to_string(), "4.25");
    }
}
