//! Integer-bucket histogram used for clique-size distributions (Fig. 9a)
//! and latency tracking in the coordinator.

use std::collections::BTreeMap;

/// Sparse histogram over `u32` values.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    buckets: BTreeMap<u32, u64>,
    count: u64,
    sum: f64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, value: u32) {
        *self.buckets.entry(value).or_insert(0) += 1;
        self.count += 1;
        self.sum += value as f64;
    }

    /// Record `n` occurrences of `value` at once. The checkpoint
    /// deserializer rebuilds a histogram from its `(value, count)`
    /// bucket pairs with this — equivalent to `n` calls to `record`.
    pub fn record_n(&mut self, value: u32, n: u64) {
        if n == 0 {
            return;
        }
        *self.buckets.entry(value).or_insert(0) += n;
        self.count += n;
        self.sum += value as f64 * n as f64;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> u32 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (&v, &c) in &self.buckets {
            acc += c;
            if acc >= target.max(1) {
                return v;
            }
        }
        *self.buckets.keys().next_back().unwrap()
    }

    pub fn max(&self) -> u32 {
        self.buckets.keys().next_back().copied().unwrap_or(0)
    }

    /// `(value, count)` pairs in ascending value order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u64)> + '_ {
        self.buckets.iter().map(|(&v, &c)| (v, c))
    }

    /// Normalized distribution `(value, fraction)`.
    pub fn distribution(&self) -> Vec<(u32, f64)> {
        let n = self.count.max(1) as f64;
        self.iter().map(|(v, c)| (v, c as f64 / n)).collect()
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (v, c) in other.iter() {
            *self.buckets.entry(v).or_insert(0) += c;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// JSON export (`{"buckets": [[v, c], ...], "count": n, "mean": m}`).
    pub fn to_json(&self) -> super::json::Json {
        use super::json::Json;
        Json::obj(vec![
            (
                "buckets",
                Json::Arr(
                    self.iter()
                        .map(|(v, c)| {
                            Json::Arr(vec![Json::Num(v as f64), Json::Num(c as f64)])
                        })
                        .collect(),
                ),
            ),
            ("count", Json::Num(self.count as f64)),
            ("mean", Json::Num(self.mean())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_means() {
        let mut h = Histogram::new();
        for v in [1, 2, 2, 3] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.0).abs() < 1e-12);
        assert_eq!(h.max(), 3);
    }

    #[test]
    fn quantiles() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.5), 50);
        assert_eq!(h.quantile(0.99), 99);
        assert_eq!(h.quantile(1.0), 100);
        assert_eq!(h.quantile(0.0), 1);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn distribution_sums_to_one() {
        let mut h = Histogram::new();
        for v in [5, 5, 7, 9] {
            h.record(v);
        }
        let total: f64 = h.distribution().iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Histogram::new();
        a.record(1);
        let mut b = Histogram::new();
        b.record(2);
        b.record(1);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![(1, 2), (2, 1)]);
    }
}
