//! Deterministic PRNG + Zipf sampling.
//!
//! We deliberately avoid external RNG crates: experiments must be
//! bit-reproducible across runs and platforms from a single `u64` seed.

/// xorshift64* — fast, well-distributed, deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. A zero seed is remapped (xorshift fixpoint).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9e37_79b9_7f4a_7c15 } else { seed },
        }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (inter-arrival times).
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        if k * 4 >= n {
            // Dense: shuffle a full index vector prefix.
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Sparse: rejection sample.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

/// Zipf(s) sampler over ranks `0..n` via a precomputed CDF + binary search.
/// Rank 0 is the most popular element.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    /// Build for `n` elements with exponent `s` (s = 0 → uniform).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "ZipfSampler over empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    /// Draw a rank in `[0, n)`.
    ///
    /// The search comparator is `f64::total_cmp` (akpc-lint L1): a weight
    /// table degenerated to NaN (e.g. a NaN exponent flowing through
    /// `powf`) must map every draw to a well-defined rank, not panic
    /// mid-`binary_search` the way `partial_cmp(..).unwrap()` did.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range(2, 5);
            assert!((2..=5).contains(&v));
            saw_lo |= v == 2;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(4);
        let mean = 2.5;
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exp(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() < 0.1, "got {got}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        for k in [1, 5, 50, 99] {
            let s = r.sample_distinct(100, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
        }
    }

    #[test]
    fn zipf_rank0_most_popular() {
        let z = ZipfSampler::new(100, 1.0);
        let mut r = Rng::new(6);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn zipf_uniform_when_s0() {
        let z = ZipfSampler::new(10, 0.0);
        let mut r = Rng::new(7);
        let mut counts = vec![0usize; 10];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        let lo = *counts.iter().min().unwrap() as f64;
        let hi = *counts.iter().max().unwrap() as f64;
        assert!(hi / lo < 1.2, "counts {counts:?}");
    }

    #[test]
    fn zipf_degenerate_nan_weights_never_panic() {
        // Regression (akpc-lint L1): a NaN exponent degenerates the whole
        // CDF to NaN through `powf` + normalization. The old
        // `partial_cmp(..).unwrap()` comparator panicked on the first
        // draw; with `total_cmp`, NaN sorts above every u ∈ [0, 1), so
        // every draw lands deterministically on rank 0.
        let z = ZipfSampler::new(8, f64::NAN);
        let mut r = Rng::new(9);
        for _ in 0..1_000 {
            let rank = z.sample(&mut r);
            assert!(rank < 8);
            assert_eq!(rank, 0, "NaN CDF must resolve deterministically");
        }
    }

    #[test]
    fn zipf_covers_domain() {
        let z = ZipfSampler::new(5, 1.2);
        let mut r = Rng::new(8);
        let mut seen = [false; 5];
        for _ in 0..10_000 {
            seen[z.sample(&mut r)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
