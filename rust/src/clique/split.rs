//! Clique Splitting (Algorithm 3, lines 2-3).
//!
//! Cliques larger than ω are recursively partitioned along the *weakest
//! co-utilization edge* of `CRM_norm(W)`: pick the minimum-weight pair
//! `(u, v)` inside the clique, seed two sub-groups with `u` and `v`, and
//! assign every other member to the side it is more strongly connected to
//! (total normalized weight). Recurse until every part is ≤ ω.

use super::CliqueSet;
use crate::crm::CrmWindow;

impl CliqueSet {
    /// Split every clique with `|c| > omega` (paper example: an 8-clique
    /// with ω=5 becomes two 4-cliques).
    pub fn split_oversized(&mut self, crm: &CrmWindow, omega: u32) {
        let oversized: Vec<usize> = self
            .iter_ids()
            .filter(|(_, c)| c.len() > omega as usize)
            .map(|(id, _)| id)
            .collect();
        for id in oversized {
            let items = self.remove(id).expect("live slot");
            for part in split_recursive(items, crm, omega as usize) {
                self.insert(part);
            }
        }
    }
}

/// Recursively split `items` until every part has `len <= omega`.
pub fn split_recursive(items: Vec<u32>, crm: &CrmWindow, omega: usize) -> Vec<Vec<u32>> {
    if items.len() <= omega {
        return vec![items];
    }
    let (a, b) = split_once(&items, crm);
    let mut out = split_recursive(a, crm, omega);
    out.extend(split_recursive(b, crm, omega));
    out
}

/// One bisection along the weakest edge.
fn split_once(items: &[u32], crm: &CrmWindow) -> (Vec<u32>, Vec<u32>) {
    debug_assert!(items.len() >= 2);
    // Weakest pair (u, v).
    let mut min_w = f32::INFINITY;
    let (mut u, mut v) = (items[0], items[1]);
    for i in 0..items.len() {
        for j in (i + 1)..items.len() {
            let w = crm.weight(items[i], items[j]);
            if w < min_w {
                min_w = w;
                u = items[i];
                v = items[j];
            }
        }
    }
    partition_by_affinity(items, u, v, crm)
}

/// Partition `items` into a `u`-side and a `v`-side by co-utilization
/// affinity: each other member joins the side it has the larger total
/// normalized weight towards. Shared by clique splitting (weakest-edge
/// bisection) and Algorithm 4's removed-edge split ([`super::adjust`]).
pub(crate) fn partition_by_affinity(
    items: &[u32],
    u: u32,
    v: u32,
    crm: &CrmWindow,
) -> (Vec<u32>, Vec<u32>) {
    let mut side_u = vec![u];
    let mut side_v = vec![v];
    for &d in items {
        if d == u || d == v {
            continue;
        }
        // Affinity = total weight towards each side's current members.
        let wu: f32 = side_u.iter().map(|&m| crm.weight(d, m)).sum();
        let wv: f32 = side_v.iter().map(|&m| crm.weight(d, m)).sum();
        // Balance ties towards the smaller side so splits cannot degenerate.
        if wu > wv || (wu == wv && side_u.len() <= side_v.len()) {
            side_u.push(d);
        } else {
            side_v.push(d);
        }
    }
    side_u.sort_unstable();
    side_v.sort_unstable();
    (side_u, side_v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crm::native::build_native;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    /// Two tight 4-bundles {0..3} and {4..7}, connected by one weak link.
    fn two_bundle_crm() -> CrmWindow {
        let mut reqs = Vec::new();
        for _ in 0..10 {
            reqs.push(req(&[0, 1, 2, 3]));
            reqs.push(req(&[4, 5, 6, 7]));
        }
        reqs.push(req(&[3, 4])); // weak bridge
        build_native(&reqs, 16, 0.0, 1.0)
    }

    #[test]
    fn splits_along_weak_bridge() {
        let crm = two_bundle_crm();
        let parts = split_recursive((0..8).collect(), &crm, 5);
        assert_eq!(parts.len(), 2);
        let mut parts = parts;
        parts.sort();
        assert_eq!(parts[0], vec![0, 1, 2, 3]);
        assert_eq!(parts[1], vec![4, 5, 6, 7]);
    }

    #[test]
    fn paper_example_8_into_4_4() {
        // ω=5, clique of 8 splits into two groups of ≤5 (paper: 4+4).
        let crm = two_bundle_crm();
        let mut set = CliqueSet::new();
        set.insert((0..8).collect());
        set.split_oversized(&crm, 5);
        set.check_invariants().unwrap();
        assert!(set.iter().all(|c| c.len() <= 5));
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn no_split_when_within_omega() {
        let crm = two_bundle_crm();
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2]);
        set.split_oversized(&crm, 5);
        assert_eq!(set.len(), 1);
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn recursion_bounds_all_parts() {
        // 16 items in one blob with uniform weights: must end ≤ ω anyway.
        let mut reqs = Vec::new();
        for a in 0..16u32 {
            for b in (a + 1)..16 {
                reqs.push(req(&[a, b]));
            }
        }
        let crm = build_native(&reqs, 16, 0.0, 1.0);
        let parts = split_recursive((0..16).collect(), &crm, 3);
        assert!(parts.iter().all(|p| p.len() <= 3 && !p.is_empty()));
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 16);
    }

    #[test]
    fn split_preserves_membership() {
        let crm = two_bundle_crm();
        let parts = split_recursive((0..8).collect(), &crm, 5);
        let mut all: Vec<u32> = parts.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
    }
}
