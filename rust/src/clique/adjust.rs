//! Adjust Previous Cliques (Algorithm 4) — incremental clique maintenance
//! from the edge diff ΔE between consecutive binary CRMs.
//!
//! * A **removed** edge `(u, v)` with both endpoints in the same clique
//!   invalidates it: the clique is split into two along that edge (members
//!   assigned to the side they are more strongly connected to — same
//!   affinity rule as clique splitting).
//! * An **added** edge with unassigned endpoints leaves them unassigned
//!   here; the `form_new` step of the surrounding Algorithm 3 pipeline
//!   greedily grows *maximal* cliques over all unassigned items (forming
//!   the pair here would fragment triangles: {u,v} would lock u and v away
//!   from a better 3-clique the same window revealed — Alg. 4 line 9's
//!   "update if any new cliques are formed" is realized by that step).
//! * Items that left the kept set (all their edges removed) degrade to
//!   unassigned singletons.

use super::CliqueSet;
use crate::crm::{CrmWindow, EdgeDiff};

impl CliqueSet {
    /// Apply Algorithm 4 in place.
    pub fn adjust(&mut self, crm: &CrmWindow, delta: &EdgeDiff) {
        for &(u, v) in &delta.removed {
            let (cu, cv) = (self.clique_id_of(u), self.clique_id_of(v));
            if let (Some(cu), Some(cv)) = (cu, cv) {
                if cu == cv {
                    let items = self.remove(cu).expect("live slot");
                    let (a, b) =
                        super::split::partition_by_affinity(&items, u, v, crm);
                    if a.len() >= 2 {
                        self.insert(a);
                    }
                    if b.len() >= 2 {
                        self.insert(b);
                    }
                    // Size-1 leftovers become unassigned (served as
                    // singleton cliques by the request path).
                }
            }
        }
        // Drop members that fell out of the kept set entirely: every clique
        // member must still be a kept item with at least one intra-clique
        // edge; otherwise the clique's co-utilization claim is stale.
        let stale: Vec<usize> = self
            .iter_ids()
            .filter(|(_, c)| {
                c.iter().any(|&d| {
                    !crm.contains(d)
                        || !c.iter().any(|&o| o != d && crm.edge(d, o))
                })
            })
            .map(|(id, _)| id)
            .collect();
        for id in stale {
            let items = self.remove(id).expect("live");
            // Re-insert the still-connected core if it remains a clique.
            let core: Vec<u32> = items
                .iter()
                .copied()
                .filter(|&d| {
                    crm.contains(d)
                        && items.iter().any(|&o| o != d && crm.edge(d, o))
                })
                .collect();
            if core.len() >= 2 {
                self.insert(core);
            }
        }

        // Added edges: nothing to do here — endpoints that are unassigned
        // are picked up by `form_new` right after (see module docs).
        let _ = &delta.added;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crm::diff_windows;
    use crate::crm::native::build_native;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    fn crm_of(groups: &[&[u32]]) -> CrmWindow {
        let mut reqs = Vec::new();
        for g in groups {
            for _ in 0..10 {
                reqs.push(req(g));
            }
        }
        reqs.push(req(&[14, 15])); // spread
        build_native(&reqs, 16, 0.1, 1.0)
    }

    #[test]
    fn removed_edge_splits_clique() {
        let prev_crm = crm_of(&[&[0, 1, 2, 3]]);
        // Next window: {0,1} and {2,3} separate.
        let curr_crm = crm_of(&[&[0, 1], &[2, 3]]);
        let delta = diff_windows(&prev_crm, &curr_crm);
        assert!(!delta.removed.is_empty());

        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2, 3]);
        set.adjust(&curr_crm, &delta);
        set.check_invariants().unwrap();
        // After splitting, no clique may span the broken edge set.
        assert_ne!(set.clique_id_of(0), set.clique_id_of(2));
    }

    #[test]
    fn added_edge_forms_pair_via_form_new() {
        let prev_crm = crm_of(&[&[0, 1]]);
        let curr_crm = crm_of(&[&[0, 1], &[4, 5]]);
        let delta = diff_windows(&prev_crm, &curr_crm);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1]);
        set.adjust(&curr_crm, &delta);
        // adjust leaves new endpoints unassigned; the pipeline's form_new
        // step picks them up.
        assert_eq!(set.clique_of(4), None);
        set.form_new(&curr_crm, None);
        set.check_invariants().unwrap();
        assert_eq!(set.clique_of(4).unwrap(), &[4, 5]);
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1]);
    }

    #[test]
    fn added_edge_into_existing_clique_no_double_assign() {
        let prev_crm = crm_of(&[&[0, 1]]);
        let curr_crm = crm_of(&[&[0, 1], &[1, 2]]);
        let delta = diff_windows(&prev_crm, &curr_crm);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1]);
        set.adjust(&curr_crm, &delta);
        set.check_invariants().unwrap();
        // 1 stays in its clique; 2 unassigned (form_new may pick it up
        // later with other unassigned items, but not steal 1).
        assert_eq!(set.clique_of(1).unwrap(), &[0, 1]);
    }

    #[test]
    fn vanished_item_dropped_from_clique() {
        let prev_crm = crm_of(&[&[0, 1, 2]]);
        // Item 2 disappears from the workload entirely.
        let curr_crm = crm_of(&[&[0, 1]]);
        let delta = diff_windows(&prev_crm, &curr_crm);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2]);
        set.adjust(&curr_crm, &delta);
        set.check_invariants().unwrap();
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1]);
        assert_eq!(set.clique_of(2), None);
    }

    #[test]
    fn unrelated_cliques_untouched() {
        let prev_crm = crm_of(&[&[0, 1], &[2, 3]]);
        let curr_crm = crm_of(&[&[0, 1], &[2, 3], &[4, 5]]);
        let delta = diff_windows(&prev_crm, &curr_crm);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1]);
        set.insert(vec![2, 3]);
        set.adjust(&curr_crm, &delta);
        set.check_invariants().unwrap();
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1]);
        assert_eq!(set.clique_of(2).unwrap(), &[2, 3]);
    }

    #[test]
    fn empty_delta_is_noop() {
        let crm = crm_of(&[&[0, 1]]);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1]);
        let before: Vec<Vec<u32>> = set.iter().map(|c| c.to_vec()).collect();
        set.adjust(&crm, &EdgeDiff::default());
        let after: Vec<Vec<u32>> = set.iter().map(|c| c.to_vec()).collect();
        assert_eq!(before, after);
    }
}
