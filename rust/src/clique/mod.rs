//! Disjoint clique construction and maintenance — Algorithms 3 & 4.
//!
//! [`CliqueSet`] holds the disjoint set `Clique(W)` of co-access groups.
//! The per-window update (Algorithm 3) is:
//!
//! 1. [`adjust`](CliqueSet::adjust) previous cliques by the edge diff ΔE
//!    (Algorithm 4) — reuse instead of recompute;
//! 2. [`form_new`](CliqueSet::form_new): greedily grow cliques over items
//!    not yet assigned (covers both the cold start and edges added between
//!    previously unassigned items);
//! 3. [`split_oversized`](CliqueSet::split_oversized): recursively split
//!    cliques larger than ω along the weakest co-utilization edges;
//! 4. [`merge_approx`](CliqueSet::merge_approx): approximate clique
//!    merging — combine `c1, c2` when `|c1 ∪ c2| = ω` and the induced edge
//!    density is ≥ γ.

pub mod adjust;
pub mod merge;
pub mod split;

use std::collections::HashMap;

use crate::crm::{CrmWindow, EdgeDiff};

/// A disjoint set of cliques over item ids.
///
/// Slots may be vacated (`None`) by merges/removals; `item_to_clique`
/// always maps every assigned item to its live slot.
#[derive(Debug, Clone, Default)]
pub struct CliqueSet {
    slots: Vec<Option<Vec<u32>>>,
    item_to_clique: HashMap<u32, usize>,
}

impl CliqueSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Full Algorithm 3 pipeline for one window.
    ///
    /// `prev` is `Clique(W-1)` (empty on cold start), `delta` the edge diff
    /// between the previous and current binary CRMs. Flags gate the CS/ACM
    /// modules for the paper's ablation variants.
    pub fn generate(
        prev: &CliqueSet,
        crm: &CrmWindow,
        delta: &EdgeDiff,
        omega: u32,
        gamma: f32,
        clique_splitting: bool,
        approx_merging: bool,
    ) -> CliqueSet {
        let mut set = prev.clone();
        set.adjust(crm, delta);
        set.form_new(crm, if clique_splitting { Some(omega) } else { None });
        if clique_splitting {
            set.split_oversized(crm, omega);
        }
        if approx_merging {
            set.merge_approx(crm, omega, gamma);
        }
        set.compact();
        set
    }

    /// Insert a clique (sorted, deduped). Panics in debug if any item is
    /// already assigned — cliques must stay disjoint.
    pub fn insert(&mut self, mut items: Vec<u32>) -> usize {
        items.sort_unstable();
        items.dedup();
        debug_assert!(
            items.iter().all(|d| !self.item_to_clique.contains_key(d)),
            "insert violates disjointness"
        );
        let id = self.slots.len();
        for &d in &items {
            self.item_to_clique.insert(d, id);
        }
        self.slots.push(Some(items));
        id
    }

    /// Remove a clique by slot id, unassigning its items.
    pub fn remove(&mut self, id: usize) -> Option<Vec<u32>> {
        let items = self.slots.get_mut(id)?.take()?;
        for d in &items {
            self.item_to_clique.remove(d);
        }
        Some(items)
    }

    /// The clique containing `item`, if any.
    pub fn clique_of(&self, item: u32) -> Option<&[u32]> {
        let id = *self.item_to_clique.get(&item)?;
        self.slots[id].as_deref()
    }

    /// Slot id of the clique containing `item`.
    pub fn clique_id_of(&self, item: u32) -> Option<usize> {
        self.item_to_clique.get(&item).copied()
    }

    /// Iterate live cliques.
    pub fn iter(&self) -> impl Iterator<Item = &[u32]> {
        self.slots.iter().filter_map(|s| s.as_deref())
    }

    /// Iterate `(slot_id, clique)`.
    pub fn iter_ids(&self) -> impl Iterator<Item = (usize, &[u32])> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_deref().map(|c| (i, c)))
    }

    /// Number of live cliques.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop vacated slots, renumbering ids.
    pub fn compact(&mut self) {
        let live: Vec<Vec<u32>> = self.slots.drain(..).flatten().collect();
        self.item_to_clique.clear();
        for (id, c) in live.iter().enumerate() {
            for &d in c {
                self.item_to_clique.insert(d, id);
            }
        }
        self.slots = live.into_iter().map(Some).collect();
    }

    /// Greedily grow cliques over items of `crm` that are not yet assigned.
    ///
    /// Nodes are visited in descending degree order; a node joins a growing
    /// clique only if it has a binary edge to **every** current member —
    /// i.e., the result is a set of true cliques of the binary CRM.
    /// `cap` bounds growth at ω when splitting is enabled (equivalent to
    /// split-after-grow but cheaper); `None` leaves sizes unbounded (the
    /// "w/o CS" variant).
    ///
    /// Degrees are one O(E) sweep over the CSR rows, and growth candidates
    /// come from the seed's neighbor row (with weights read off the
    /// entries) — never an O(U²) rescan of the unassigned set. Ordering is
    /// decision-identical to the dense implementation: both sorts use
    /// total-order comparators, so the candidate *sequence* does not
    /// depend on how candidates were enumerated.
    pub fn form_new(&mut self, crm: &CrmWindow, cap: Option<u32>) {
        let k = crm.k();
        if k == 0 {
            return;
        }
        // Unassigned kept items (ascending) + row-indexed membership mask.
        let mut unassigned_row = vec![false; k];
        let mut unassigned: Vec<u32> = Vec::new();
        for (row, &d) in crm.active.iter().enumerate() {
            if !self.item_to_clique.contains_key(&d) {
                unassigned_row[row] = true;
                unassigned.push(d);
            }
        }
        // O(E) degrees: binary neighbors that are themselves unassigned.
        let degs: HashMap<u32, usize> = unassigned
            .iter()
            .map(|&u| {
                let deg = crm
                    .neighbors(u)
                    .filter(|&(v, _, is_edge)| {
                        is_edge && unassigned_row[crm.row_index(v).expect("kept")]
                    })
                    .count();
                (u, deg)
            })
            .collect();
        let mut order = unassigned;
        order.sort_unstable_by(|&a, &b| degs[&b].cmp(&degs[&a]).then(a.cmp(&b)));

        let mut assigned: std::collections::HashSet<u32> = Default::default();
        for &seed in &order {
            if assigned.contains(&seed) || degs[&seed] == 0 {
                continue;
            }
            let mut members = vec![seed];
            // Candidates straight from the seed's CSR row, sorted by
            // co-access weight to the seed, desc (ties by id).
            let mut cands: Vec<(u32, f32)> = crm
                .neighbors(seed)
                .filter(|&(v, _, is_edge)| {
                    is_edge
                        && unassigned_row[crm.row_index(v).expect("kept")]
                        && !assigned.contains(&v)
                })
                .map(|(v, w, _)| (v, w))
                .collect();
            cands.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            for (v, _) in cands {
                if let Some(cap) = cap {
                    if members.len() >= cap as usize {
                        break;
                    }
                }
                if members.iter().all(|&m| crm.edge(m, v)) {
                    members.push(v);
                }
            }
            if members.len() >= 2 {
                for &m in &members {
                    assigned.insert(m);
                }
                self.insert(members);
            }
        }
    }

    /// Verify internal invariants (tests / proptest harness).
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for (id, c) in self.iter_ids() {
            anyhow::ensure!(!c.is_empty(), "empty clique in slot {id}");
            anyhow::ensure!(
                c.windows(2).all(|w| w[0] < w[1]),
                "clique {id} not sorted"
            );
            for &d in c {
                anyhow::ensure!(seen.insert(d), "item {d} in two cliques");
                anyhow::ensure!(
                    self.item_to_clique.get(&d) == Some(&id),
                    "index out of sync for item {d}"
                );
            }
        }
        anyhow::ensure!(
            seen.len() == self.item_to_clique.len(),
            "stale index entries"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crm::native::build_native;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    /// CRM where the given pairs each co-occur `w` times (plus one weak
    /// global pair so normalization has spread).
    fn crm_from(pairs: &[(u32, u32, usize)]) -> CrmWindow {
        let mut reqs = Vec::new();
        for &(a, b, w) in pairs {
            for _ in 0..w {
                reqs.push(req(&[a, b]));
            }
        }
        build_native(&reqs, 32, 0.0, 1.0)
    }

    #[test]
    fn insert_and_lookup() {
        let mut s = CliqueSet::new();
        let id = s.insert(vec![3, 1, 2]);
        assert_eq!(s.clique_of(2), Some(&[1, 2, 3][..]));
        assert_eq!(s.clique_id_of(1), Some(id));
        assert_eq!(s.len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_unassigns() {
        let mut s = CliqueSet::new();
        let id = s.insert(vec![1, 2]);
        assert_eq!(s.remove(id), Some(vec![1, 2]));
        assert_eq!(s.clique_of(1), None);
        assert_eq!(s.len(), 0);
        s.check_invariants().unwrap();
    }

    #[test]
    fn form_new_finds_triangle() {
        let crm = crm_from(&[(0, 1, 5), (1, 2, 5), (0, 2, 5), (8, 9, 1)]);
        let mut s = CliqueSet::new();
        s.form_new(&crm, None);
        s.check_invariants().unwrap();
        let c = s.clique_of(0).unwrap().to_vec();
        assert_eq!(c, vec![0, 1, 2]);
    }

    #[test]
    fn form_new_respects_cap() {
        // 5-clique in the CRM, cap 3.
        let mut pairs = vec![];
        for a in 0..5u32 {
            for b in (a + 1)..5 {
                pairs.push((a, b, 5));
            }
        }
        pairs.push((10, 11, 1));
        let crm = crm_from(&pairs);
        let mut s = CliqueSet::new();
        s.form_new(&crm, Some(3));
        s.check_invariants().unwrap();
        for c in s.iter() {
            assert!(c.len() <= 3, "clique {c:?} exceeds cap");
        }
    }

    #[test]
    fn form_new_skips_assigned_items() {
        let crm = crm_from(&[(0, 1, 5), (1, 2, 5), (0, 2, 5), (8, 9, 1)]);
        let mut s = CliqueSet::new();
        s.insert(vec![1]); // pre-assigned elsewhere
        s.form_new(&crm, None);
        s.check_invariants().unwrap();
        // 1 must not be stolen; 0-2 can pair up.
        assert_eq!(s.clique_of(1), Some(&[1][..]));
    }

    #[test]
    fn form_new_only_true_cliques() {
        // Path 0-1-2 (no 0-2 edge): no triangle allowed.
        let crm = crm_from(&[(0, 1, 5), (1, 2, 5), (8, 9, 1)]);
        let mut s = CliqueSet::new();
        s.form_new(&crm, None);
        s.check_invariants().unwrap();
        for c in s.iter() {
            for i in 0..c.len() {
                for j in (i + 1)..c.len() {
                    assert!(crm.edge(c[i], c[j]), "non-edge inside {c:?}");
                }
            }
        }
    }

    #[test]
    fn compact_renumbers() {
        let mut s = CliqueSet::new();
        let a = s.insert(vec![1, 2]);
        let _b = s.insert(vec![3, 4]);
        s.remove(a);
        s.compact();
        assert_eq!(s.len(), 1);
        assert_eq!(s.clique_id_of(3), Some(0));
        s.check_invariants().unwrap();
    }

    #[test]
    fn generate_cold_start_pipeline() {
        // Two ground-truth bundles co-accessed heavily.
        let mut reqs = Vec::new();
        for _ in 0..20 {
            reqs.push(req(&[0, 1, 2]));
            reqs.push(req(&[5, 6]));
        }
        let crm = build_native(&reqs, 16, 0.2, 1.0);
        let set = CliqueSet::generate(
            &CliqueSet::new(),
            &crm,
            &crate::crm::diff_windows(&CrmWindow::default(), &crm),
            5,
            0.85,
            true,
            true,
        );
        set.check_invariants().unwrap();
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1, 2]);
        assert_eq!(set.clique_of(5).unwrap(), &[5, 6]);
    }
}
