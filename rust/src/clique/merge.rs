//! Approximate Clique Merging (Algorithm 3, lines 4-10).
//!
//! Two cliques `c1, c2` merge into `U = c1 ∪ c2` when
//!
//! * `|U| = ω` (the target size — merging reconstructs full-size packs), and
//! * the edge density of the subgraph induced by `U` in `CRM_bin(W)` is at
//!   least γ: `|E_U| / (ω·(ω−1)/2) ≥ γ`.
//!
//! Candidate pairs are evaluated in descending density order so the best
//! near-cliques merge first; each clique merges at most once per window
//! (a merged clique has size ω and cannot satisfy `|U| = ω` again).

use super::CliqueSet;
use crate::crm::CrmWindow;

/// Edge density of the union of two cliques in the binary CRM.
pub fn union_density(c1: &[u32], c2: &[u32], crm: &CrmWindow) -> f32 {
    let u: Vec<u32> = c1.iter().chain(c2.iter()).copied().collect();
    let n = u.len();
    if n < 2 {
        return 1.0;
    }
    let mut edges = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            if crm.edge(u[i], u[j]) {
                edges += 1;
            }
        }
    }
    let max = n * (n - 1) / 2;
    edges as f32 / max as f32
}

impl CliqueSet {
    /// Run one approximate-merging pass.
    ///
    /// Candidate enumeration is **size-bucketed**: cliques are grouped by
    /// `|c|`, and only buckets `s` × `ω − s` are crossed — the pairs with
    /// `|c1| + |c2| = ω`, which is the paper's merge precondition. The
    /// all-pairs O(m²) scan this replaces evaluated every pair just to
    /// discard the size mismatches. Ranking is fully deterministic
    /// (density desc, slot ids as tie-break); with distinct densities it
    /// is identical to the previous enumeration.
    pub fn merge_approx(&mut self, crm: &CrmWindow, omega: u32, gamma: f32) {
        let omega = omega as usize;
        let ids: Vec<(usize, usize)> = {
            let live: Vec<(usize, &[u32])> = self.iter_ids().collect();
            // size -> positions in `live` (only sizes < ω can pair up).
            let mut by_size: std::collections::BTreeMap<usize, Vec<usize>> =
                Default::default();
            for (pos, (_, c)) in live.iter().enumerate() {
                if c.len() < omega {
                    by_size.entry(c.len()).or_default().push(pos);
                }
            }
            let mut pairs = Vec::new();
            for (&s1, b1) in &by_size {
                let s2 = omega - s1; // both < ω, so s2 >= 1
                if s2 < s1 {
                    break; // every remaining bucket pairs downward only
                }
                if s1 == s2 {
                    for x in 0..b1.len() {
                        for y in (x + 1)..b1.len() {
                            let (ia, ca) = live[b1[x]];
                            let (ib, cb) = live[b1[y]];
                            pairs.push((ia, ib, union_density(ca, cb, crm)));
                        }
                    }
                } else if let Some(b2) = by_size.get(&s2) {
                    for &x in b1 {
                        for &y in b2 {
                            let (ia, ca) = live[x];
                            let (ib, cb) = live[y];
                            let d = union_density(ca, cb, crm);
                            pairs.push((ia.min(ib), ia.max(ib), d));
                        }
                    }
                }
            }
            pairs.retain(|&(_, _, d)| d >= gamma);
            // Density desc under a total order (akpc-lint L1), slot ids
            // as the deterministic tie-break.
            pairs.sort_unstable_by(|x, y| {
                y.2.total_cmp(&x.2).then((x.0, x.1).cmp(&(y.0, y.1)))
            });
            pairs.into_iter().map(|(a, b, _)| (a, b)).collect()
        };

        let mut consumed = std::collections::HashSet::new();
        for (a, b) in ids {
            if consumed.contains(&a) || consumed.contains(&b) {
                continue;
            }
            let ca = self.remove(a).expect("live");
            let cb = self.remove(b).expect("live");
            let mut u = ca;
            u.extend(cb);
            self.insert(u);
            consumed.insert(a);
            consumed.insert(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crm::native::build_native;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    /// CRM over a near-clique {0..4}: all 10 edges except (3,4).
    fn near_clique_crm(missing: &[(u32, u32)]) -> CrmWindow {
        let mut reqs = Vec::new();
        for a in 0..5u32 {
            for b in (a + 1)..5 {
                if !missing.contains(&(a, b)) {
                    for _ in 0..5 {
                        reqs.push(req(&[a, b]));
                    }
                }
            }
        }
        reqs.push(req(&[10, 11])); // normalization spread
        build_native(&reqs, 16, 0.1, 1.0)
    }

    #[test]
    fn density_computation() {
        let crm = near_clique_crm(&[(3, 4)]);
        // Union {0,1,2} ∪ {3,4}: 9 of 10 edges.
        let d = union_density(&[0, 1, 2], &[3, 4], &crm);
        assert!((d - 0.9).abs() < 1e-6, "{d}");
    }

    #[test]
    fn merges_near_clique_at_gamma_085() {
        let crm = near_clique_crm(&[(3, 4)]);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2]);
        set.insert(vec![3, 4]);
        set.merge_approx(&crm, 5, 0.85);
        set.check_invariants().unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn no_merge_below_gamma() {
        // Remove 3 edges -> density 0.7 < 0.85.
        let crm = near_clique_crm(&[(3, 4), (0, 3), (1, 4)]);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2]);
        set.insert(vec![3, 4]);
        set.merge_approx(&crm, 5, 0.85);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn no_merge_when_union_size_differs_from_omega() {
        let crm = near_clique_crm(&[]);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1]);
        set.insert(vec![2, 3]);
        // union = 4 != ω=5 -> no merge even at density 1.
        set.merge_approx(&crm, 5, 0.5);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn each_clique_merges_at_most_once() {
        // Three cliques: {0,1,2}, {3,4}, {5,6}: both small ones are
        // mergeable with {0,1,2}, but only one merge may happen.
        let mut reqs = Vec::new();
        for a in 0..7u32 {
            for b in (a + 1)..7 {
                for _ in 0..5 {
                    reqs.push(req(&[a, b]));
                }
            }
        }
        reqs.push(req(&[10, 11]));
        let crm = build_native(&reqs, 16, 0.1, 1.0);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2]);
        set.insert(vec![3, 4]);
        set.insert(vec![5, 6]);
        set.merge_approx(&crm, 5, 0.85);
        set.check_invariants().unwrap();
        assert_eq!(set.len(), 2);
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = set.iter().map(|c| c.len()).collect();
            s.sort_unstable();
            s
        };
        assert_eq!(sizes, vec![2, 5]);
    }

    #[test]
    fn best_density_pair_wins() {
        // {0,1,2} can merge with {3,4} (density 1.0) or {5,6} (lower).
        let mut reqs = Vec::new();
        let full: &[u32] = &[0, 1, 2, 3, 4];
        for (i, &a) in full.iter().enumerate() {
            for &b in &full[i + 1..] {
                for _ in 0..5 {
                    reqs.push(req(&[a, b]));
                }
            }
        }
        // {5,6} weakly tied to {0,1,2}: only 2 cross edges.
        for _ in 0..5 {
            reqs.push(req(&[5, 6]));
            reqs.push(req(&[0, 5]));
            reqs.push(req(&[1, 6]));
        }
        let crm = build_native(&reqs, 16, 0.1, 1.0);
        let mut set = CliqueSet::new();
        set.insert(vec![0, 1, 2]);
        set.insert(vec![3, 4]);
        set.insert(vec![5, 6]);
        set.merge_approx(&crm, 5, 0.5);
        // {0,1,2} must have merged with {3,4}, not {5,6}.
        assert_eq!(set.clique_of(0).unwrap(), &[0, 1, 2, 3, 4]);
        assert_eq!(set.clique_of(5).unwrap(), &[5, 6]);
    }
}
