//! Checkpoint/restore: [`HandoffState`] on disk (DESIGN.md §14.3).
//!
//! A checkpoint is the *exact* portable state PR-8's elastic handoff
//! already defined — config, clique-generation state, live copies,
//! quiesce clock, pending window — plus what a crash-restarted daemon
//! additionally needs: the admission watermark (so resent frames at or
//! below it are rejected as duplicates, never double-served) and the
//! merged metrics of everything served so far (so counters stay monotone
//! across the restart, the same contract hot-reload epochs keep).
//!
//! ## File format (`akpc.ckpt`)
//!
//! ```text
//!   magic  "AKCP"
//!   version u32 = 1                      (all integers little-endian)
//!   body:
//!     cfg TOML text     (len-prefixed bytes; exact round-trip)
//!     engine u8, tick_mode u8
//!     clock f64, watermark f64
//!     gen   { omega, windows, clique_gen_secs, prev_crm as
//!             (active, CSR entries), cliques in slot order,
//!             histogram (value, count) pairs, recent batches }
//!     copies   [key u64, size u32, server u32, expiry f64]
//!     pending  [requests]
//!     prior metrics epoch (optional: full snapshot incl. per-shard)
//!   checksum u64 = FNV-1a 64 over magic..body
//! ```
//!
//! Writes go to `akpc.ckpt.tmp` then `fs::rename` — atomic on POSIX, so
//! a crash (or an injected `checkpoint-write` fault) mid-write never
//! corrupts the previous checkpoint. Reads verify magic, version, and
//! checksum before deserializing; a truncated or bit-flipped file is a
//! clean error, not a garbage restore.
//!
//! Not captured: the donor's `Instant` epoch (wall-clock anchor for
//! live-mode `time: None` requests) — an `Instant` does not survive a
//! process, so restore re-anchors at `Instant::now()`. Trace-timed
//! ingest (every exactness test) is unaffected.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::cache::{CopyRecord, CostLedger};
use crate::clique::CliqueSet;
use crate::config::AkpcConfig;
use crate::coordinator::{HandoffState, MetricsSnapshot, ShardStats};
use crate::crm::CrmWindow;
use crate::runtime::CrmEngine;
use crate::trace::model::Request;
use crate::util::Histogram;

use crate::algo::GenState;
use crate::coordinator::TickMode;

const MAGIC: &[u8; 4] = b"AKCP";
const VERSION: u32 = 1;

/// Fixed checkpoint file name inside `--checkpoint-dir`; the atomic
/// rename always replaces the whole file, so one name is one slot.
pub const CKPT_FILE: &str = "akpc.ckpt";

/// Everything a restarted daemon resumes from.
pub struct Checkpoint {
    /// The fleet state, byte-for-byte what `Coordinator::resume` needs.
    pub state: HandoffState,
    /// Admission floor: the highest request time admitted before the
    /// checkpoint. A restarted daemon rejects times ≤ this as duplicates
    /// (`rejected_late`), which is what makes client resend-from-ack
    /// exactly-once end to end.
    pub watermark: f64,
    /// Merged metrics of all epochs up to the checkpoint (already
    /// handoff-normalized); the restarted daemon seeds its prior-epoch
    /// list with this so `/metrics` counters stay monotone.
    pub prior: Option<MetricsSnapshot>,
}

// ---- byte-level helpers -------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        Self { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
    fn finish(mut self) -> Vec<u8> {
        let sum = fnv1a(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "truncated checkpoint (need {n} bytes at offset {})",
            self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.u64()? as usize;
        self.take(n)
    }
    /// Bounded element count for a length prefix (corruption guard: a
    /// bogus length must error, not attempt a huge allocation).
    fn count(&mut self) -> anyhow::Result<usize> {
        let n = self.u64()? as usize;
        anyhow::ensure!(
            n <= self.buf.len(),
            "checkpoint length prefix {n} exceeds file size"
        );
        Ok(n)
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---- domain encodings ---------------------------------------------------

fn put_hist(w: &mut Writer, h: &Histogram) {
    let pairs: Vec<(u32, u64)> = h.iter().collect();
    w.u64(pairs.len() as u64);
    for (v, c) in pairs {
        w.u32(v);
        w.u64(c);
    }
}

fn get_hist(r: &mut Reader) -> anyhow::Result<Histogram> {
    let n = r.count()?;
    let mut h = Histogram::new();
    for _ in 0..n {
        let v = r.u32()?;
        let c = r.u64()?;
        h.record_n(v, c);
    }
    Ok(h)
}

fn put_request(w: &mut Writer, req: &Request) {
    w.f64(req.time);
    w.u32(req.server);
    w.u32(req.items.len() as u32);
    for &d in &req.items {
        w.u32(d);
    }
}

fn get_request(r: &mut Reader) -> anyhow::Result<Request> {
    let time = r.f64()?;
    let server = r.u32()?;
    let k = r.u32()? as usize;
    anyhow::ensure!(k <= r.buf.len(), "request item count {k} exceeds file size");
    let mut items = Vec::with_capacity(k);
    for _ in 0..k {
        items.push(r.u32()?);
    }
    Ok(Request::new(items, server, time))
}

fn put_requests(w: &mut Writer, reqs: &[Request]) {
    w.u64(reqs.len() as u64);
    for r in reqs {
        put_request(w, r);
    }
}

fn get_requests(r: &mut Reader) -> anyhow::Result<Vec<Request>> {
    let n = r.count()?;
    (0..n).map(|_| get_request(r)).collect()
}

fn put_crm(w: &mut Writer, crm: &CrmWindow) {
    w.u64(crm.active.len() as u64);
    for &d in &crm.active {
        w.u32(d);
    }
    // Walk the CSR rows back out as (row, id, w, edge) entries; the
    // restore rebuilds through the same `from_entries` constructor the
    // window diff uses, so row ordering is reproduced exactly.
    let mut entries: Vec<(u32, u32, f32, bool)> = Vec::new();
    for (row, &d) in crm.active.iter().enumerate() {
        for (id, wgt, is_edge) in crm.neighbors(d) {
            entries.push((row as u32, id, wgt, is_edge));
        }
    }
    w.u64(entries.len() as u64);
    for (row, id, wgt, is_edge) in entries {
        w.u32(row);
        w.u32(id);
        w.f32(wgt);
        w.u8(u8::from(is_edge));
    }
}

fn get_crm(r: &mut Reader) -> anyhow::Result<CrmWindow> {
    let k = r.count()?;
    let mut active = Vec::with_capacity(k);
    for _ in 0..k {
        active.push(r.u32()?);
    }
    let n = r.count()?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let row = r.u32()?;
        let id = r.u32()?;
        let wgt = r.f32()?;
        let is_edge = r.u8()? != 0;
        anyhow::ensure!((row as usize) < k.max(1), "CSR row {row} out of range");
        entries.push(crate::crm::CsrEntry {
            row,
            id,
            w: wgt,
            is_edge,
        });
    }
    Ok(CrmWindow::from_entries(active, entries))
}

fn put_cliques(w: &mut Writer, set: &CliqueSet) {
    // Exported sets are always compacted (CliqueSet::generate ends with
    // compact()), so serializing live cliques in slot order and
    // re-inserting ascending reproduces identical slot ids.
    let cliques: Vec<&[u32]> = set.iter_ids().map(|(_, c)| c).collect();
    w.u64(cliques.len() as u64);
    for c in cliques {
        w.u64(c.len() as u64);
        for &d in c {
            w.u32(d);
        }
    }
}

fn get_cliques(r: &mut Reader) -> anyhow::Result<CliqueSet> {
    let n = r.count()?;
    let mut set = CliqueSet::new();
    for _ in 0..n {
        let k = r.count()?;
        let mut items = Vec::with_capacity(k);
        for _ in 0..k {
            items.push(r.u32()?);
        }
        set.insert(items);
    }
    Ok(set)
}

fn put_ledger(w: &mut Writer, l: &CostLedger) {
    w.f64(l.c_p);
    w.f64(l.c_t);
    w.u64(l.transfers);
    w.u64(l.full_hits);
    w.u64(l.misses);
    w.u64(l.requests);
    w.u64(l.items_delivered);
    w.u64(l.items_requested);
}

fn get_ledger(r: &mut Reader) -> anyhow::Result<CostLedger> {
    Ok(CostLedger {
        c_p: r.f64()?,
        c_t: r.f64()?,
        transfers: r.u64()?,
        full_hits: r.u64()?,
        misses: r.u64()?,
        requests: r.u64()?,
        items_delivered: r.u64()?,
        items_requested: r.u64()?,
    })
}

fn put_snapshot(w: &mut Writer, m: &MetricsSnapshot) {
    w.bytes(m.policy.as_bytes());
    w.bytes(m.engine.as_bytes());
    put_ledger(w, &m.ledger);
    w.u64(m.served);
    w.u64(m.windows);
    w.u64(m.live_cliques as u64);
    w.f64(m.clique_gen_secs);
    put_hist(w, &m.clique_hist);
    put_hist(w, &m.latency_us);
    w.u64(m.per_shard.len() as u64);
    for s in &m.per_shard {
        w.u64(s.shard as u64);
        put_ledger(w, &s.ledger);
        w.u64(s.served);
        w.u64(s.retentions);
        w.u64(s.live_entries as u64);
        w.u64(s.snapshot_version);
        w.f64(s.last_time);
        w.u64(s.queue_depth as u64);
        put_hist(w, &s.latency_us);
    }
}

fn get_snapshot(r: &mut Reader) -> anyhow::Result<MetricsSnapshot> {
    let policy = String::from_utf8(r.bytes()?.to_vec())?;
    let engine = String::from_utf8(r.bytes()?.to_vec())?;
    let ledger = get_ledger(r)?;
    let served = r.u64()?;
    let windows = r.u64()?;
    let live_cliques = r.u64()? as usize;
    let clique_gen_secs = r.f64()?;
    let clique_hist = get_hist(r)?;
    let latency_us = get_hist(r)?;
    let n = r.count()?;
    let mut per_shard = Vec::with_capacity(n);
    for _ in 0..n {
        let shard = r.u64()? as usize;
        let ledger = get_ledger(r)?;
        let served = r.u64()?;
        let retentions = r.u64()?;
        let live_entries = r.u64()? as usize;
        let snapshot_version = r.u64()?;
        let last_time = r.f64()?;
        let queue_depth = r.u64()? as usize;
        let latency_us = get_hist(r)?;
        per_shard.push(ShardStats {
            shard,
            ledger,
            served,
            latency_us,
            retentions,
            live_entries,
            snapshot_version,
            last_time,
            queue_depth,
        });
    }
    Ok(MetricsSnapshot {
        policy,
        engine,
        ledger,
        served,
        windows,
        live_cliques,
        clique_hist,
        clique_gen_secs,
        latency_us,
        per_shard,
    })
}

// ---- top level ----------------------------------------------------------

/// Serialize a checkpoint to bytes (magic + version + body + checksum).
pub fn to_bytes(ck: &Checkpoint) -> Vec<u8> {
    let mut w = Writer::new();
    let st = &ck.state;
    w.bytes(st.cfg.to_toml().as_bytes());
    w.u8(match st.engine {
        CrmEngine::Native => 0,
        CrmEngine::Xla => 1,
    });
    w.u8(match st.tick_mode {
        TickMode::Sync => 0,
        TickMode::Async => 1,
    });
    w.f64(st.clock);
    w.f64(ck.watermark);
    // GenState.
    w.u32(st.gen.omega);
    w.u64(st.gen.windows);
    w.f64(st.gen.clique_gen_secs);
    put_crm(&mut w, &st.gen.prev_crm);
    put_cliques(&mut w, &st.gen.cliques);
    put_hist(&mut w, &st.gen.hist);
    w.u64(st.gen.recent.len() as u64);
    for batch in &st.gen.recent {
        put_requests(&mut w, batch);
    }
    // Copies.
    w.u64(st.copies.len() as u64);
    for c in &st.copies {
        w.u64(c.key);
        w.u32(c.size);
        w.u32(c.server);
        w.f64(c.expiry);
    }
    put_requests(&mut w, &st.pending);
    // Prior metrics epoch.
    match &ck.prior {
        None => w.u8(0),
        Some(m) => {
            w.u8(1);
            put_snapshot(&mut w, m);
        }
    }
    w.finish()
}

/// Deserialize and verify a checkpoint (magic, version, checksum).
pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
    anyhow::ensure!(bytes.len() >= MAGIC.len() + 4 + 8, "checkpoint too short");
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let sum = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    anyhow::ensure!(fnv1a(body) == sum, "checkpoint checksum mismatch");
    let mut r = Reader { buf: body, pos: 0 };
    anyhow::ensure!(r.take(4)? == MAGIC, "not an AKCP checkpoint");
    let version = r.u32()?;
    anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");

    let cfg = AkpcConfig::from_toml_str(std::str::from_utf8(r.bytes()?)?)?;
    let engine = match r.u8()? {
        0 => CrmEngine::Native,
        1 => CrmEngine::Xla,
        t => anyhow::bail!("unknown engine tag {t}"),
    };
    let tick_mode = match r.u8()? {
        0 => TickMode::Sync,
        1 => TickMode::Async,
        t => anyhow::bail!("unknown tick-mode tag {t}"),
    };
    let clock = r.f64()?;
    let watermark = r.f64()?;
    let omega = r.u32()?;
    let windows = r.u64()?;
    let clique_gen_secs = r.f64()?;
    let prev_crm = get_crm(&mut r)?;
    let cliques = get_cliques(&mut r)?;
    let hist = get_hist(&mut r)?;
    let n_batches = r.count()?;
    let mut recent = VecDeque::with_capacity(n_batches);
    for _ in 0..n_batches {
        recent.push_back(get_requests(&mut r)?);
    }
    let n_copies = r.count()?;
    let mut copies = Vec::with_capacity(n_copies);
    for _ in 0..n_copies {
        copies.push(CopyRecord {
            key: r.u64()?,
            size: r.u32()?,
            server: r.u32()?,
            expiry: r.f64()?,
        });
    }
    let pending = get_requests(&mut r)?;
    let prior = match r.u8()? {
        0 => None,
        _ => Some(get_snapshot(&mut r)?),
    };
    anyhow::ensure!(r.pos == r.buf.len(), "trailing bytes in checkpoint");

    let gen = GenState {
        omega,
        prev_crm,
        cliques,
        hist,
        recent,
        clique_gen_secs,
        windows,
    };
    Ok(Checkpoint {
        state: HandoffState {
            cfg,
            engine,
            tick_mode,
            gen,
            copies,
            clock,
            pending,
            // An Instant cannot cross a process boundary; live-mode
            // wall-clock timestamps re-anchor at restore time.
            start: Instant::now(),
        },
        watermark,
        prior,
    })
}

/// Path of the checkpoint slot inside `dir`.
pub fn slot_path(dir: &Path) -> PathBuf {
    dir.join(CKPT_FILE)
}

/// Write a checkpoint into `dir` atomically: serialize, write
/// `akpc.ckpt.tmp`, fsync, rename over `akpc.ckpt`. An injected
/// `checkpoint-write` fault (or any IO error) leaves the previous
/// checkpoint untouched.
pub fn write_to_dir(dir: &Path, ck: &Checkpoint) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(
        !crate::fault::should_fail("checkpoint-write", None),
        "injected fault: checkpoint write failure"
    );
    std::fs::create_dir_all(dir)?;
    let bytes = to_bytes(ck);
    let tmp = dir.join(format!("{CKPT_FILE}.tmp"));
    let fin = slot_path(dir);
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &fin)?;
    Ok(fin)
}

/// Load the checkpoint slot from `dir`; `Ok(None)` if none exists yet.
pub fn read_from_dir(dir: &Path) -> anyhow::Result<Option<Checkpoint>> {
    let path = slot_path(dir);
    match std::fs::read(&path) {
        Ok(bytes) => Ok(Some(from_bytes(&bytes).map_err(|e| {
            anyhow::anyhow!("{}: {e}", path.display())
        })?)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(e.into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, ServeRequest};
    use crate::util::tempdir::TempDir;

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 4,
            batch_size: 10,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    /// Drive a coordinator to a non-trivial state and checkpoint it.
    fn live_checkpoint() -> Checkpoint {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        for i in 0..25 {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 4,
                    time: Some(f64::from(i) * 0.05),
                })
                .unwrap();
        }
        let state = coord.checkpoint_state().unwrap();
        let prior = coord.metrics().unwrap();
        let clock = state.clock();
        drop(coord);
        Checkpoint {
            state,
            watermark: clock,
            prior: Some(prior.into_handoff_epoch()),
        }
    }

    #[test]
    fn roundtrip_preserves_state_and_serving_behavior() {
        let ck = live_checkpoint();
        let n_copies = ck.state.n_copies();
        let n_pending = ck.state.n_pending();
        let clock = ck.state.clock();
        let bytes = to_bytes(&ck);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.state.n_copies(), n_copies);
        assert_eq!(back.state.n_pending(), n_pending);
        assert_eq!(back.state.clock(), clock);
        assert_eq!(back.watermark, ck.watermark);
        let prior = back.prior.as_ref().unwrap();
        assert_eq!(prior.served, 25);
        // The restored fleet serves the learned {1,2} pack — the clique
        // set and cache content survived the byte round-trip.
        let coord = Coordinator::resume(back.state, 2).unwrap();
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3,
                time: Some(10.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        drop(coord);
    }

    #[test]
    fn dir_slot_roundtrip_and_missing_dir() {
        let dir = TempDir::new("akpc-ckpt").unwrap();
        assert!(read_from_dir(dir.path()).unwrap().is_none());
        let ck = live_checkpoint();
        write_to_dir(dir.path(), &ck).unwrap();
        let back = read_from_dir(dir.path()).unwrap().unwrap();
        assert_eq!(back.state.n_copies(), ck.state.n_copies());
        // Overwrite is atomic: a second write replaces the slot.
        write_to_dir(dir.path(), &back).unwrap();
        assert!(read_from_dir(dir.path()).unwrap().is_some());
    }

    #[test]
    fn corruption_is_rejected() {
        let ck = live_checkpoint();
        let bytes = to_bytes(&ck);
        // Bit-flip in the body → checksum mismatch.
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(from_bytes(&bad).is_err());
        // Truncation → clean error.
        assert!(from_bytes(&bytes[..bytes.len() - 9]).is_err());
        // Wrong magic.
        let mut bad = bytes;
        bad[0] = b'X';
        assert!(from_bytes(&bad).is_err());
    }

    #[test]
    fn injected_write_failure_leaves_previous_slot_intact() {
        let dir = TempDir::new("akpc-ckpt-fault").unwrap();
        let ck = live_checkpoint();
        write_to_dir(dir.path(), &ck).unwrap();
        crate::fault::arm(
            "checkpoint-write",
            None,
            crate::fault::FaultAction::Fail,
            0,
        );
        assert!(write_to_dir(dir.path(), &ck).is_err());
        // The previous checkpoint still reads back clean.
        let back = read_from_dir(dir.path()).unwrap().unwrap();
        assert_eq!(back.state.n_copies(), ck.state.n_copies());
        crate::fault::disarm_all();
    }
}
