//! Deterministic, seeded fault plans (DESIGN.md §14.1).
//!
//! A [`FaultPlan`] is an ordered schedule of [`FaultEvent`]s — *which*
//! fault, *where* (shard), *when* (window boundary index) — either
//! written out explicitly, parsed from compact specs
//! (`"shard-panic@2:1"` = panic shard 1 at window boundary 2), or drawn
//! from a seeded xorshift generator so a property test can sweep ~30
//! random schedules reproducibly. The plan itself never touches the
//! global injection registry; the [supervisor](crate::fault::supervisor)
//! arms each event at the right moment and drives recovery.

use crate::util::Rng;

/// The fault matrix: everything the harness can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The target shard's actor thread panics on its next serve.
    ShardPanic,
    /// The target shard's actor wedges (sleeps past the reply timeout)
    /// on its next serve.
    ShardStall,
    /// The ingest connection drops mid-stream; the client reconnects
    /// and resumes from its acked watermark.
    IngestDrop,
    /// The next checkpoint write fails (disk error); the previous
    /// checkpoint must stay intact (atomic rename).
    CheckpointFail,
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::ShardPanic => "shard-panic",
            FaultKind::ShardStall => "shard-stall",
            FaultKind::IngestDrop => "ingest-drop",
            FaultKind::CheckpointFail => "checkpoint-fail",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "shard-panic" => Ok(Self::ShardPanic),
            "shard-stall" => Ok(Self::ShardStall),
            "ingest-drop" => Ok(Self::IngestDrop),
            "checkpoint-fail" => Ok(Self::CheckpointFail),
            _ => anyhow::bail!("unknown fault kind `{s}`"),
        }
    }
}

/// One scheduled fault: `kind` against `shard` at window boundary
/// `window` (the fault arms when the coordinator has closed exactly
/// `window` windows, and fires on the next matching hit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub window: u64,
    /// Target shard (ignored by `IngestDrop` / `CheckpointFail`).
    pub shard: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Compact spec: `kind@window[:shard]`, shard defaulting to 0.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let (kind, rest) = spec
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault spec `{spec}` needs kind@window[:shard]"))?;
        let (window, shard) = match rest.split_once(':') {
            Some((w, s)) => (w, s.parse()?),
            None => (rest, 0),
        };
        Ok(Self {
            window: window.parse()?,
            shard,
            kind: FaultKind::parse(kind)?,
        })
    }

    pub fn spec(&self) -> String {
        format!("{}@{}:{}", self.kind.as_str(), self.window, self.shard)
    }
}

/// An ordered fault schedule, sorted by window boundary.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.window);
        Self { events }
    }

    /// Parse a comma-separated list of compact specs
    /// (`"shard-panic@2:1,ingest-drop@4"`).
    pub fn parse(specs: &str) -> anyhow::Result<Self> {
        let events = specs
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| FaultEvent::parse(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self::new(events))
    }

    /// Draw a random schedule: `n_events` faults over `n_windows` window
    /// boundaries (≥ 1 — a boundary-0 fault would precede any learned
    /// state) against `n_shards` shards, reproducible per `seed`. All
    /// four kinds are drawn; recovery-path kinds dominate the weighting
    /// (panic/stall 3:3:1:1 vs drop/checkpoint) since they exercise the
    /// exactness contract the property test pins.
    pub fn random(seed: u64, n_events: usize, n_windows: u64, n_shards: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA17_FA17_FA17_FA17);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let kind = match rng.next_u64() % 8 {
                0..=2 => FaultKind::ShardPanic,
                3..=5 => FaultKind::ShardStall,
                6 => FaultKind::IngestDrop,
                _ => FaultKind::CheckpointFail,
            };
            events.push(FaultEvent {
                window: 1 + rng.next_u64() % n_windows.max(1),
                shard: (rng.next_u64() % n_shards.max(1) as u64) as usize,
                kind,
            });
        }
        Self::new(events)
    }

    /// Events scheduled at window boundary `w` (ascending shard order —
    /// Vec order after the sort is stable for equal windows).
    pub fn at_window(&self, w: u64) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter().filter(move |e| e.window == w)
    }

    pub fn spec(&self) -> String {
        self.events
            .iter()
            .map(FaultEvent::spec)
            .collect::<Vec<_>>()
            .join(",")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip() {
        let p = FaultPlan::parse("shard-panic@2:1, ingest-drop@4, shard-stall@1:0").unwrap();
        assert_eq!(p.events.len(), 3);
        assert_eq!(p.events[0].kind, FaultKind::ShardStall, "sorted by window");
        let back = FaultPlan::parse(&p.spec()).unwrap();
        assert_eq!(back.events, p.events);
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(FaultPlan::parse("nonsense@1").is_err());
        assert!(FaultPlan::parse("shard-panic").is_err());
        assert!(FaultPlan::parse("shard-panic@x").is_err());
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let a = FaultPlan::random(7, 10, 5, 4);
        let b = FaultPlan::random(7, 10, 5, 4);
        assert_eq!(a.events, b.events);
        assert!(a.events.iter().all(|e| e.window >= 1 && e.window <= 5));
        assert!(a.events.iter().all(|e| e.shard < 4));
        let c = FaultPlan::random(8, 10, 5, 4);
        assert_ne!(a.events, c.events, "seed changes the schedule");
    }

    #[test]
    fn at_window_filters() {
        let p = FaultPlan::parse("shard-panic@2:1,shard-stall@2:0,ingest-drop@3").unwrap();
        assert_eq!(p.at_window(2).count(), 2);
        assert_eq!(p.at_window(3).count(), 1);
        assert_eq!(p.at_window(1).count(), 0);
    }
}
