//! Shard supervision: drive a trace through a [`Coordinator`] under a
//! [`FaultPlan`], recover from every injected failure, and account for
//! the recovery exactly (DESIGN.md §14.2).
//!
//! The supervisor is the offline twin of the serving daemon's recovery
//! path — same primitives (`lost_shard`, `recover`, the checkpoint
//! writer, the admission-watermark dedup), driven synchronously so the
//! exactness contract is testable:
//!
//! > recovered total cost == never-faulted oracle total
//! >                         + Σ re-transfer charges for copies restored
//! >                           from each dead shard's shadow
//!
//! ## Why the shadow is exact (the gap-1 argument)
//!
//! Shadows (per-shard live copies + stats) are captured at every window
//! boundary, *after* the synchronous snapshot install. A shard fault is
//! armed at a boundary and fires at the **top of the next Serve arm**
//! that reaches the doomed shard — before that serve mutates anything,
//! and `Coordinator::serve` only pushes a request into the window
//! batcher *after* the shard replies. So between the last shadow and
//! the fault there are zero mutations on the doomed shard (no serves —
//! the firing serve is the first since arming; no installs — those only
//! happen at boundaries). The shadow *is* the dead shard's state at
//! fault time, the failed request is neither served nor batched, and
//! re-submitting it to the recovered fleet replays history with a gap
//! of exactly zero requests.
//!
//! Stalled shards (wedged, not dead) eventually wake and serve the
//! doomed request into their *old* core — which the recovered fleet
//! discarded in favor of the shadow, and whose response channel is
//! gone. The write is invisible; the old actor drains and exits once
//! the retired fleet's senders drop.

use std::collections::VecDeque;
use std::path::PathBuf;

use crate::cache::CopyRecord;
use crate::config::AkpcConfig;
use crate::coordinator::{
    set_reply_timeout_ms, Coordinator, MetricsSnapshot, ServeRequest, ShardLost, ShardStats,
};
use crate::fault::checkpoint::{self, Checkpoint};
use crate::fault::plan::{FaultKind, FaultPlan};
use crate::fault::{arm, disarm_all, FaultAction};
use crate::runtime::CrmEngine;
use crate::trace::model::Request;

/// Knobs for one supervised run.
pub struct FaultRunOptions {
    pub cfg: AkpcConfig,
    pub engine: CrmEngine,
    pub n_shards: usize,
    pub plan: FaultPlan,
    /// How long an injected stall sleeps. Must exceed
    /// `reply_timeout_ms` or the stall is invisible.
    pub stall_ms: u64,
    /// Coordinator reply timeout while this run is active (swapped in
    /// on entry, restored on exit). Keep small so stall detection does
    /// not dominate test wall-clock.
    pub reply_timeout_ms: u64,
    /// If set, a checkpoint is written at every window boundary (and
    /// `checkpoint-fail` events have something to break).
    pub checkpoint_dir: Option<PathBuf>,
}

impl FaultRunOptions {
    pub fn new(cfg: AkpcConfig, engine: CrmEngine, n_shards: usize, plan: FaultPlan) -> Self {
        Self {
            cfg,
            engine,
            n_shards,
            plan,
            stall_ms: 400,
            reply_timeout_ms: 100,
            checkpoint_dir: None,
        }
    }
}

/// What a supervised run did and what it cost.
#[derive(Debug, Clone)]
pub struct FaultRunReport {
    /// Final metrics, merged across every fleet epoch (pre-recovery
    /// epochs fold in exactly like hot-reload epochs do).
    pub snapshot: MetricsSnapshot,
    /// `snapshot.ledger.total()`, for callers that only want the number.
    pub total_cost: f64,
    /// Fleet rebuilds performed (shard panics + stalls detected).
    pub recoveries: u64,
    /// Σ re-transfer cost charged for copies restored from dead-shard
    /// shadows — the exact gap between this run and a faultless oracle.
    pub recharges: f64,
    /// Requests re-submitted after a recovery (the in-flight casualty
    /// of each fault; always ≤ `recoveries`... equal, in fact).
    pub resubmitted: u64,
    /// Replayed frames rejected by the admission watermark after an
    /// injected ingest drop (exactly-once: duplicates never serve).
    pub duplicates_rejected: u64,
    /// Window-boundary checkpoints that landed on disk.
    pub checkpoints_written: u64,
    /// Checkpoint writes that failed under an injected fault (the
    /// previous slot stays intact — atomic rename).
    pub checkpoint_failures: u64,
}

/// RAII: swap the coordinator reply timeout in, restore the old value
/// on scope exit (the registry and timeout are process-global, so fault
/// runs must not leak their aggressive settings into other tests).
struct TimeoutGuard {
    old_ms: u64,
}

impl TimeoutGuard {
    fn set(ms: u64) -> Self {
        Self {
            old_ms: set_reply_timeout_ms(ms),
        }
    }
}

impl Drop for TimeoutGuard {
    fn drop(&mut self) {
        set_reply_timeout_ms(self.old_ms);
        disarm_all();
    }
}

/// Capture per-shard shadows: `(stats, live copies)` for every shard,
/// taken at a window boundary so the gap-1 argument applies.
fn capture_shadows(
    coord: &Coordinator,
    n_shards: usize,
) -> anyhow::Result<Vec<(ShardStats, Vec<CopyRecord>)>> {
    let m = coord.metrics()?;
    let mut out = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let stats = m
            .per_shard
            .iter()
            .find(|p| p.shard == s)
            .cloned()
            .unwrap_or_else(|| ShardStats {
                shard: s,
                ..ShardStats::default()
            });
        let copies = coord.export_shard_copies(s)?;
        out.push((stats, copies));
    }
    Ok(out)
}

/// Run `trace` through a supervised coordinator fleet under
/// `opts.plan`, recovering from every injected fault.
///
/// The trace must be globally time-ordered (strictly increasing
/// `time`), which is what makes the admission-watermark dedup and the
/// expiry-sweep transparency arguments hold; every generator in
/// [`crate::trace`] produces such traces.
///
/// # Errors
///
/// Propagates coordinator failures that are *not* attributable to a
/// supervised shard (e.g. the clique-gen worker dying), and checkpoint
/// IO errors other than injected ones.
pub fn run_fault_plan(opts: &FaultRunOptions, trace: &[Request]) -> anyhow::Result<FaultRunReport> {
    let batch = opts.cfg.batch_size.max(1) as u64;
    let _guard = TimeoutGuard::set(opts.reply_timeout_ms);
    disarm_all();

    let mut coord = Some(Coordinator::start(
        opts.cfg.clone(),
        opts.engine,
        opts.n_shards,
    )?);
    let n_shards = opts.n_shards.max(1);
    let mut prior: Vec<MetricsSnapshot> = Vec::new();
    let mut shadows = capture_shadows(coord.as_ref().unwrap(), n_shards)?;

    let mut queue: VecDeque<Request> = trace.iter().cloned().collect();
    // Frames delivered since the last boundary — what an ingest drop
    // makes the "client" redeliver.
    let mut recent: VecDeque<Request> = VecDeque::new();
    let mut watermark = f64::NEG_INFINITY;
    let mut served: u64 = 0;
    let mut boundary: u64 = 0;

    let mut recoveries = 0u64;
    let mut recharges = 0.0f64;
    let mut resubmitted = 0u64;
    let mut duplicates_rejected = 0u64;
    let mut checkpoints_written = 0u64;
    let mut checkpoint_failures = 0u64;

    while let Some(req) = queue.pop_front() {
        // Admission watermark: exactly what the daemon's reorder stage
        // enforces — a frame at or below the high-water mark is a
        // duplicate (ingest-drop redelivery) and must never serve.
        if req.time <= watermark {
            duplicates_rejected += 1;
            continue;
        }
        let sreq = ServeRequest {
            items: req.items.clone(),
            server: req.server,
            time: Some(req.time),
        };
        match coord.as_ref().unwrap().serve(sreq) {
            Ok(_) => {
                watermark = req.time;
                served += 1;
                recent.push_back(req);
                if recent.len() as u64 > batch {
                    recent.pop_front();
                }
                if served % batch != 0 {
                    continue;
                }
                // ---- window boundary ----
                boundary += 1;
                let c = coord.as_ref().unwrap();
                // Shadows first: state *after* this boundary's install,
                // *before* anything armed below can fire.
                shadows = capture_shadows(c, n_shards)?;
                for ev in opts.plan.at_window(boundary) {
                    match ev.kind {
                        FaultKind::ShardPanic => {
                            arm("shard-serve", Some(ev.shard % n_shards), FaultAction::Panic, 0);
                        }
                        FaultKind::ShardStall => arm(
                            "shard-serve",
                            Some(ev.shard % n_shards),
                            FaultAction::Stall(std::time::Duration::from_millis(opts.stall_ms)),
                            0,
                        ),
                        FaultKind::IngestDrop => {
                            // The connection died after the batch was
                            // acked server-side but before the client
                            // saw the ack: the client reconnects and
                            // redelivers everything past its last acked
                            // watermark. All of it is duplicate.
                            for r in recent.iter().rev() {
                                queue.push_front(r.clone());
                            }
                        }
                        FaultKind::CheckpointFail => {
                            arm("checkpoint-write", None, FaultAction::Fail, 0);
                        }
                    }
                }
                if let Some(dir) = &opts.checkpoint_dir {
                    let ck = Checkpoint {
                        state: c.checkpoint_state()?,
                        watermark,
                        prior: prior.last().cloned(),
                    };
                    match checkpoint::write_to_dir(dir, &ck) {
                        Ok(_) => checkpoints_written += 1,
                        Err(_) => checkpoint_failures += 1,
                    }
                }
            }
            Err(e) => {
                // Attribute the failure to a shard: the typed error
                // knows which mailbox timed out / disconnected; a
                // panicked actor is also visible via its join handle.
                let lost = e
                    .downcast_ref::<ShardLost>()
                    .and_then(|l| l.shard)
                    .or_else(|| coord.as_ref().unwrap().lost_shard());
                let Some(lost) = lost else {
                    return Err(e);
                };
                let lost = lost % n_shards;
                let (stats, copies) = shadows[lost].clone();
                let retiring = coord.take().unwrap();
                let (next, retired, recharge) = retiring.recover(lost, copies, stats)?;
                coord = Some(next);
                prior.push(retired.into_handoff_epoch());
                recoveries += 1;
                recharges += recharge;
                // Fresh fleet, fresh shadows (state is the recovery
                // baseline; the next boundary refreshes them again).
                shadows = capture_shadows(coord.as_ref().unwrap(), n_shards)?;
                // The failed request was neither served nor batched —
                // replay it first (its time is above the watermark, so
                // it passes admission exactly once).
                resubmitted += 1;
                queue.push_front(req);
            }
        }
    }

    let last = coord.as_ref().unwrap().metrics()?;
    let snapshot = MetricsSnapshot::merge_epochs(&prior, last);
    let total_cost = snapshot.ledger.total();
    Ok(FaultRunReport {
        snapshot,
        total_cost,
        recoveries,
        recharges,
        resubmitted,
        duplicates_rejected,
        checkpoints_written,
        checkpoint_failures,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::plan::FaultEvent;
    use crate::trace::generator::{self, GeneratorParams, TraceKind};
    use crate::util::tempdir::TempDir;
    use std::sync::Mutex;

    // The injection registry and reply timeout are process-global:
    // supervised runs must not interleave.
    static LOCK: Mutex<()> = Mutex::new(());

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 24,
            n_servers: 6,
            batch_size: 12,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    fn trace(n: usize) -> Vec<Request> {
        let mut p = GeneratorParams::netflix(24, 6, n);
        p.seed = 7;
        generator::generate(&p, TraceKind::Netflix).requests
    }

    fn run(plan: FaultPlan, dir: Option<PathBuf>) -> FaultRunReport {
        let mut opts = FaultRunOptions::new(cfg(), CrmEngine::Native, 3, plan);
        opts.checkpoint_dir = dir;
        run_fault_plan(&opts, &trace(120)).unwrap()
    }

    #[test]
    fn empty_plan_matches_plain_coordinator() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let report = run(FaultPlan::default(), None);
        assert_eq!(report.recoveries, 0);
        assert_eq!(report.recharges, 0.0);
        assert_eq!(report.snapshot.served, 120);

        let coord = Coordinator::start(cfg(), CrmEngine::Native, 3).unwrap();
        for r in trace(120) {
            coord
                .serve(ServeRequest {
                    items: r.items,
                    server: r.server,
                    time: Some(r.time),
                })
                .unwrap();
        }
        let oracle = coord.metrics().unwrap();
        assert_eq!(report.snapshot.served, oracle.served);
        assert!((report.total_cost - oracle.ledger.total()).abs() <= 1e-9 * oracle.ledger.total().abs().max(1.0));
        drop(coord);
    }

    #[test]
    fn panic_recovery_charges_exactly_the_recharge() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let oracle = run(FaultPlan::default(), None);
        let report = run(
            FaultPlan::new(vec![FaultEvent {
                window: 2,
                shard: 1,
                kind: FaultKind::ShardPanic,
            }]),
            None,
        );
        assert_eq!(report.recoveries, 1);
        assert_eq!(report.resubmitted, 1);
        assert_eq!(report.snapshot.served, oracle.snapshot.served);
        let want = oracle.total_cost + report.recharges;
        assert!(
            (report.total_cost - want).abs() <= 1e-9 * want.abs().max(1.0),
            "faulted {} vs oracle+recharge {}",
            report.total_cost,
            want
        );
    }

    #[test]
    fn ingest_drop_duplicates_are_rejected() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let oracle = run(FaultPlan::default(), None);
        let report = run(FaultPlan::parse("ingest-drop@2").unwrap(), None);
        assert!(report.duplicates_rejected > 0);
        assert_eq!(report.recoveries, 0);
        assert_eq!(report.snapshot.served, oracle.snapshot.served);
        assert!((report.total_cost - oracle.total_cost).abs() <= 1e-9 * oracle.total_cost.abs().max(1.0));
    }

    #[test]
    fn checkpoint_fail_is_counted_and_survived() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let dir = TempDir::new("akpc-fault-ckpt").unwrap();
        let report = run(
            FaultPlan::parse("checkpoint-fail@2").unwrap(),
            Some(dir.path().to_path_buf()),
        );
        assert_eq!(report.checkpoint_failures, 1);
        assert!(report.checkpoints_written >= 1);
        // The surviving slot still parses.
        assert!(checkpoint::read_from_dir(dir.path()).unwrap().is_some());
    }
}
