//! The fault-injection point (DESIGN.md §14.1).
//!
//! A process-global registry of *armed* faults, compiled permanently
//! into the hot paths it guards but **zero-cost when empty**: every
//! hook site calls [`fire`]/[`should_fail`], which is a single relaxed
//! atomic load unless a test or `akpc exp faults` has armed something.
//! The panic / sleep themselves live in *this* module, so the guarded
//! modules (`coordinator/`, `serve/`) stay clean under akpc-lint L3
//! (no panics on the hot path — the injected panic *is* the experiment,
//! not a code path a production request can reach).
//!
//! Sites currently compiled in:
//!
//! | site | location | actions |
//! |---|---|---|
//! | `shard-serve` | shard actor, top of the Serve arm | Panic, Stall |
//! | `checkpoint-write` | checkpoint writer, before the tmp write | Fail |
//! | `ingest-frame` | ingest pumps, per admitted frame | Fail (connection drop) |
//!
//! Arms are **one-shot**: a fault that fires is consumed. `after`
//! counts matching hits to skip first (0 = fire on the next hit), which
//! is how a plan expresses "drop the connection after k frames" or
//! "panic shard 2 on its next serve".

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What an armed fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic the calling thread (a shard-actor crash).
    Panic,
    /// Sleep the calling thread for the given duration (a wedged actor;
    /// pick it well above the coordinator reply timeout).
    Stall(Duration),
    /// Make the guarded operation report failure ([`should_fail`]
    /// returns `true`): a checkpoint write error, a dropped connection.
    Fail,
}

/// One armed fault in the global registry.
#[derive(Debug, Clone)]
struct ArmedFault {
    site: &'static str,
    /// Shard filter: `Some(i)` fires only for shard `i`; `None` fires
    /// for any hit on the site.
    shard: Option<usize>,
    action: FaultAction,
    /// Matching hits to skip before firing (decremented per match).
    after: u64,
}

/// Fast-path guard: number of armed faults. The hook sites read this
/// with one relaxed load and return immediately when it is zero, so an
/// unarmed binary pays one predictable-branch atomic per site hit.
static ARMED: AtomicUsize = AtomicUsize::new(0);

static REGISTRY: Mutex<Vec<ArmedFault>> = Mutex::new(Vec::new());

fn with_registry<T>(f: impl FnOnce(&mut Vec<ArmedFault>) -> T) -> T {
    let mut reg = REGISTRY
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner);
    let out = f(&mut reg);
    ARMED.store(reg.len(), Ordering::Relaxed);
    out
}

/// Arm a one-shot fault: `action` fires at hook `site` (for `shard`, if
/// given) after skipping `after` matching hits. Tests and the
/// fault-plan driver call this; nothing arms faults in production.
pub fn arm(site: &'static str, shard: Option<usize>, action: FaultAction, after: u64) {
    with_registry(|reg| {
        reg.push(ArmedFault {
            site,
            shard,
            action,
            after,
        });
    });
}

/// Disarm everything (test teardown; the registry is process-global, so
/// fault tests serialize on a lock and clear it between cases).
pub fn disarm_all() {
    with_registry(Vec::clear);
}

/// Number of currently armed faults.
pub fn armed() -> usize {
    ARMED.load(Ordering::Relaxed)
}

/// Take the action armed for this hit, if any (consumes the arm).
fn take(site: &str, shard: Option<usize>) -> Option<FaultAction> {
    if ARMED.load(Ordering::Relaxed) == 0 {
        return None;
    }
    with_registry(|reg| {
        let pos = reg.iter_mut().position(|a| {
            a.site == site && (a.shard.is_none() || a.shard == shard)
        })?;
        if reg[pos].after > 0 {
            reg[pos].after -= 1;
            return None;
        }
        Some(reg.swap_remove(pos).action)
    })
}

/// Hook for active faults (panic / stall): a no-op single atomic load
/// unless armed. Call at the top of the guarded operation, *before* any
/// state mutation, so a fired fault leaves state exactly as it was.
pub fn fire(site: &str, shard: Option<usize>) {
    match take(site, shard) {
        None | Some(FaultAction::Fail) => {}
        Some(FaultAction::Panic) => {
            panic!("injected fault: {site} shard={shard:?} (FaultAction::Panic)")
        }
        Some(FaultAction::Stall(d)) => std::thread::sleep(d),
    }
}

/// Hook for failure-result faults: `true` = the guarded operation must
/// report an error this time (consumes the arm). Panic/Stall arms on
/// the same site still execute here, so a site can use either hook.
pub fn should_fail(site: &str, shard: Option<usize>) -> bool {
    match take(site, shard) {
        None => false,
        Some(FaultAction::Fail) => true,
        Some(FaultAction::Panic) => {
            panic!("injected fault: {site} shard={shard:?} (FaultAction::Panic)")
        }
        Some(FaultAction::Stall(d)) => {
            std::thread::sleep(d);
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; these tests must not interleave
    // with each other (or with tests/fault.rs, which runs in a separate
    // test binary and serializes on its own lock).
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn unarmed_hooks_are_inert() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        disarm_all();
        assert_eq!(armed(), 0);
        fire("shard-serve", Some(0));
        assert!(!should_fail("checkpoint-write", None));
    }

    #[test]
    fn fail_arm_is_one_shot() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        disarm_all();
        arm("checkpoint-write", None, FaultAction::Fail, 0);
        assert_eq!(armed(), 1);
        assert!(should_fail("checkpoint-write", None));
        assert!(!should_fail("checkpoint-write", None), "consumed");
        assert_eq!(armed(), 0);
    }

    #[test]
    fn after_skips_hits_and_shard_filter_matches() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        disarm_all();
        arm("ingest-frame", None, FaultAction::Fail, 2);
        assert!(!should_fail("ingest-frame", None)); // skip 1
        assert!(!should_fail("ingest-frame", None)); // skip 2
        assert!(should_fail("ingest-frame", None)); // fires
        arm("shard-serve", Some(3), FaultAction::Fail, 0);
        assert!(!should_fail("shard-serve", Some(1)), "wrong shard");
        assert!(should_fail("shard-serve", Some(3)));
        disarm_all();
    }

    #[test]
    fn panic_action_panics() {
        let _g = LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        disarm_all();
        arm("shard-serve", Some(0), FaultAction::Panic, 0);
        let r = std::panic::catch_unwind(|| fire("shard-serve", Some(0)));
        assert!(r.is_err());
        assert_eq!(armed(), 0);
    }
}
