//! Fault tolerance (DESIGN.md §14): deterministic fault injection,
//! shard supervision/recovery, and checkpoint/restore.
//!
//! The layer answers one question for the serving stack: *what does a
//! failure cost, exactly?* Every fault the harness can inject — a shard
//! actor panicking or wedging mid-serve, an ingest connection dying, a
//! checkpoint write failing — has a recovery path whose cost is pinned
//! against a never-faulted oracle:
//!
//! > recovered total == oracle total + Σ transfer charges for the
//! >                    copies re-fetched onto the rebuilt shard
//!
//! Four pieces:
//!
//! * [`inject`] — the process-global registry of armed faults and the
//!   zero-cost-when-empty hooks ([`fire`] / [`should_fail`]) compiled
//!   into the guarded hot paths.
//! * [`plan`] — seeded, ordered fault schedules ([`FaultPlan`]),
//!   parseable from compact specs (`shard-panic@2:1`) or drawn
//!   reproducibly for property sweeps.
//! * [`supervisor`] — the offline driver: runs a trace under a plan,
//!   detects lost shards via typed [`ShardLost`](crate::coordinator::ShardLost)
//!   errors and join-handle watches, rebuilds the fleet from per-shard
//!   shadows, and reports the exact recharge.
//! * [`checkpoint`] — [`HandoffState`](crate::coordinator::HandoffState)
//!   on disk: length-prefixed, checksummed, atomically renamed; what
//!   `akpc serve --checkpoint-dir` crash-restarts from.

pub mod checkpoint;
pub mod inject;
pub mod plan;
pub mod supervisor;

pub use checkpoint::{read_from_dir, write_to_dir, Checkpoint};
pub use inject::{arm, armed, disarm_all, fire, should_fail, FaultAction};
pub use plan::{FaultEvent, FaultKind, FaultPlan};
pub use supervisor::{run_fault_plan, FaultRunOptions, FaultRunReport};
