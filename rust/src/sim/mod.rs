//! Event-driven CDN simulator: drives a [`CachePolicy`] over a [`Trace`]
//! with the paper's batched-window timeline (Fig. 3) and produces a
//! [`SimReport`].

pub mod replay;
pub mod report;

pub use replay::{replay_sharded, replay_sharded_stream, ReplayMode, ShardedReport};
pub use report::SimReport;

use crate::algo::CachePolicy;
use crate::trace::model::Trace;

/// Run `policy` over `trace` with clique-generation windows of
/// `batch_size` requests.
///
/// Timeline semantics (Fig. 3): requests of batch *i* are served under the
/// packing computed from batches *< i* (the Clique Generation Module runs
/// asynchronously on the *closed* window); `end_batch` is invoked after the
/// batch is fully served. Offline policies receive the whole trace via
/// `prepare` first.
///
/// **Deprecated shim** (DESIGN.md §8): this is now a thin wrapper over
/// [`crate::run::drive_trace`] with the trace lent through a
/// [`MemorySource`](crate::trace::stream::MemorySource) and no observer —
/// prefer [`crate::run::RunSpec`], which adds policy-by-name
/// construction, workload materialization, and streaming observers on
/// the identical code path.
pub fn run(policy: &mut dyn CachePolicy, trace: &Trace, batch_size: usize) -> SimReport {
    let mut source = crate::trace::stream::MemorySource::new(trace);
    crate::run::drive_trace(policy, &mut source, batch_size, &mut crate::run::NullObserver)
        .expect("in-memory trace replay cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Akpc, DpGreedy, NoPacking, Opt, PackCache2};
    use crate::config::AkpcConfig;
    use crate::trace::generator::netflix_like;

    // Table-II shape: the paper's per-server request density (~3 requests
    // per Δt per server). Much denser configurations reward AKPC's packed
    // storage so much (caching is charged per *requested* item — Table I)
    // that it can undercut the greedy clairvoyant OPT.
    fn small_cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 60,
            n_servers: 600,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    fn small_trace() -> Trace {
        netflix_like(60, 600, 20_000, 7)
    }

    #[test]
    fn all_policies_complete_and_account() {
        let cfg = small_cfg();
        let trace = small_trace();
        let mut policies: Vec<Box<dyn CachePolicy>> = vec![
            Box::new(NoPacking::new(&cfg)),
            Box::new(PackCache2::new(&cfg)),
            Box::new(DpGreedy::new(&cfg)),
            Box::new(Akpc::new(&cfg)),
            Box::new(Akpc::new(&cfg.without_cs_acm())),
            Box::new(Opt::new(&cfg)),
        ];
        for p in policies.iter_mut() {
            let rep = run(p.as_mut(), &trace, cfg.batch_size);
            assert_eq!(rep.ledger.requests, trace.len() as u64);
            assert!(rep.ledger.total() > 0.0, "{} zero cost", rep.name);
            assert!(rep.ledger.c_t >= 0.0 && rep.ledger.c_p >= 0.0);
        }
    }

    #[test]
    fn cost_ordering_matches_paper_fig5() {
        // OPT ≤ AKPC ≤ PackCache ≤ NoPacking on a co-access-heavy trace.
        let cfg = small_cfg();
        let trace = small_trace();
        let total = |mut p: Box<dyn CachePolicy>| -> f64 {
            run(p.as_mut(), &trace, cfg.batch_size).ledger.total()
        };
        let opt = total(Box::new(Opt::new(&cfg)));
        let akpc = total(Box::new(Akpc::new(&cfg)));
        let pc = total(Box::new(PackCache2::new(&cfg)));
        let np = total(Box::new(NoPacking::new(&cfg)));
        assert!(opt <= akpc, "OPT {opt} vs AKPC {akpc}");
        assert!(akpc < pc, "AKPC {akpc} vs PackCache {pc}");
        assert!(pc <= np * 1.001, "PackCache {pc} vs NoPacking {np}");
    }

    #[test]
    fn deterministic_replay() {
        let cfg = small_cfg();
        let trace = small_trace();
        let r1 = run(&mut Akpc::new(&cfg), &trace, cfg.batch_size);
        let r2 = run(&mut Akpc::new(&cfg), &trace, cfg.batch_size);
        assert_eq!(r1.ledger.c_p, r2.ledger.c_p);
        assert_eq!(r1.ledger.c_t, r2.ledger.c_t);
    }
}
