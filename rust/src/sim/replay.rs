//! Trace replay through the sharded online coordinator — the bridge
//! between the offline simulator and the serving path, and the driver the
//! shard-scaling experiments/benches use (DESIGN.md §2.3).
//!
//! Two modes:
//!
//! * [`ReplayMode::Ordered`] — one driver thread submits the trace in time
//!   order with the synchronous window barrier. Deterministic: the
//!   per-shard ledgers sum to a single-leader run's ledger on the same
//!   trace (the acceptance check `assert_shard_sum_matches` encodes).
//! * [`ReplayMode::Parallel`] — one client thread per shard replays that
//!   shard's request subsequence concurrently (async window ticks). This
//!   is the throughput configuration; window composition becomes
//!   arrival-order dependent, so costs may differ slightly run to run.

use crate::cache::CostLedger;
use crate::config::AkpcConfig;
use crate::coordinator::{Coordinator, MetricsSnapshot, ServeRequest, TickMode};
use crate::runtime::CrmEngine;
use crate::trace::model::Trace;
use crate::trace::stream::{MemorySource, TraceSource};

/// Bounded per-shard routing queue for the streaming parallel replay:
/// deep enough to keep shard threads busy, shallow enough that the
/// in-flight request memory stays a constant per shard.
pub const SHARD_CHANNEL_CAP: usize = 1_024;

/// Replay scheduling strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayMode {
    /// Single driver, global time order, synchronous window ticks.
    Ordered,
    /// One client thread per shard, asynchronous window ticks.
    Parallel,
}

/// Outcome of a sharded replay.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// Aggregated (cross-shard merged) metrics at shutdown.
    pub metrics: MetricsSnapshot,
    pub n_shards: usize,
    pub mode: ReplayMode,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
}

impl ShardedReport {
    /// Per-shard ledgers (index = shard id).
    pub fn shard_ledgers(&self) -> Vec<CostLedger> {
        self.metrics
            .per_shard
            .iter()
            .map(|s| s.ledger.clone())
            .collect()
    }

    /// Sum of the per-shard ledger totals (equals `metrics.ledger.total()`
    /// up to summation order).
    pub fn shard_sum(&self) -> f64 {
        self.metrics
            .per_shard
            .iter()
            .map(|s| s.ledger.total())
            .sum()
    }

    /// One human-readable summary row for scaling tables.
    pub fn row(&self) -> String {
        format!(
            "shards={:<3} mode={:<8} total={:>12.1}  {:>9.0} req/s  {:.2}s",
            self.n_shards,
            format!("{:?}", self.mode).to_lowercase(),
            self.metrics.ledger.total(),
            self.requests_per_sec,
            self.wall_secs,
        )
    }
}

/// Replay `trace` through an `n_shards` coordinator; returns the final
/// metrics (the coordinator is shut down before returning). Thin
/// materialized wrapper over [`replay_sharded_stream`].
pub fn replay_sharded(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    trace: &Trace,
    n_shards: usize,
    mode: ReplayMode,
) -> anyhow::Result<ShardedReport> {
    let mut source = MemorySource::new(trace);
    replay_sharded_stream(cfg, engine, &mut source, n_shards, mode)
}

/// Replay a streaming [`TraceSource`] through an `n_shards` coordinator —
/// the coordinator's `WindowBatcher` fills straight from the stream, so
/// peak replay-side memory is one chunk plus the bounded routing queues
/// (DESIGN.md §10.5).
///
/// * `Ordered` — the driver thread pulls chunks and submits every
///   request in global time order through the synchronous window
///   barrier; ledger-equivalent to a single-leader streamed replay.
/// * `Parallel` — one client thread per shard; the driver routes each
///   request to its shard's bounded channel (capacity
///   [`SHARD_CHANNEL_CAP`]), preserving per-shard time order while
///   shards serve concurrently.
pub fn replay_sharded_stream(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    source: &mut dyn TraceSource,
    n_shards: usize,
    mode: ReplayMode,
) -> anyhow::Result<ShardedReport> {
    let tick = match mode {
        ReplayMode::Ordered => TickMode::Sync,
        ReplayMode::Parallel => TickMode::Async,
    };
    let coord = Coordinator::start_with(cfg.clone(), engine, n_shards, tick)?;
    let n_shards = coord.n_shards();
    let wall = std::time::Instant::now();
    let mut served = 0usize;
    let mut chunk = Vec::new();

    match mode {
        ReplayMode::Ordered => {
            while source.next_chunk(&mut chunk)? {
                for r in chunk.drain(..) {
                    coord.serve(ServeRequest {
                        items: r.items,
                        server: r.server,
                        time: Some(r.time),
                    })?;
                    served += 1;
                }
            }
        }
        ReplayMode::Parallel => {
            let mut txs = Vec::with_capacity(n_shards);
            let mut handles = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let (tx, rx) = std::sync::mpsc::sync_channel::<crate::trace::model::Request>(
                    SHARD_CHANNEL_CAP,
                );
                let client = coord.client();
                handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
                    for r in rx {
                        client.serve(ServeRequest {
                            items: r.items,
                            server: r.server,
                            time: Some(r.time),
                        })?;
                    }
                    Ok(())
                }));
                txs.push(tx);
            }
            // Route in global order; each shard's channel preserves its
            // subsequence order. A send error means the shard thread
            // died — stop routing and surface its error via join.
            // Routing uses the coordinator's own Placement so harness
            // and shard ownership can never disagree.
            let placement = coord.placement();
            let mut routing_broken = false;
            'route: while source.next_chunk(&mut chunk)? {
                for r in chunk.drain(..) {
                    let shard = placement.shard_of(r.server);
                    if txs[shard].send(r).is_err() {
                        routing_broken = true;
                        break 'route;
                    }
                    served += 1;
                }
            }
            drop(txs);
            for h in handles {
                h.join()
                    .map_err(|_| anyhow::anyhow!("replay client panicked"))??;
            }
            anyhow::ensure!(!routing_broken, "replay client exited early");
        }
    }

    let wall_secs = wall.elapsed().as_secs_f64();
    let metrics = coord.shutdown();
    Ok(ShardedReport {
        metrics,
        n_shards,
        mode,
        wall_secs,
        requests_per_sec: served as f64 / wall_secs.max(1e-12),
    })
}

/// The tentpole determinism check: per-shard ledger totals must sum to the
/// single-leader total within `1e-9` (relative — the only permitted
/// difference is floating-point summation order).
pub fn assert_shard_sum_matches(report: &ShardedReport, single_leader_total: f64) {
    let sum = report.shard_sum();
    let tol = 1e-9 * single_leader_total.abs().max(1.0);
    assert!(
        (sum - single_leader_total).abs() <= tol,
        "{}-shard ledger sum {} != single-leader total {} (diff {:.3e}, tol {:.3e})",
        report.n_shards,
        sum,
        single_leader_total,
        (sum - single_leader_total).abs(),
        tol
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Akpc;
    use crate::trace::generator::netflix_like;

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 40,
            n_servers: 24,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn ordered_replay_matches_simulator() {
        let cfg = cfg();
        let trace = netflix_like(cfg.n_items, cfg.n_servers, 4_000, 41);
        let mut policy = Akpc::new(&cfg);
        let sim = crate::sim::run(&mut policy, &trace, cfg.batch_size);

        for n_shards in [1usize, 3] {
            let rep = replay_sharded(
                &cfg,
                CrmEngine::Native,
                &trace,
                n_shards,
                ReplayMode::Ordered,
            )
            .unwrap();
            assert_eq!(rep.metrics.ledger.requests, trace.len() as u64);
            assert_eq!(rep.metrics.ledger.full_hits, sim.ledger.full_hits);
            assert_eq!(rep.metrics.ledger.transfers, sim.ledger.transfers);
            assert_shard_sum_matches(&rep, sim.ledger.total());
        }
    }

    #[test]
    fn parallel_replay_completes_and_accounts() {
        let cfg = cfg();
        let trace = netflix_like(cfg.n_items, cfg.n_servers, 4_000, 42);
        let rep = replay_sharded(
            &cfg,
            CrmEngine::Native,
            &trace,
            4,
            ReplayMode::Parallel,
        )
        .unwrap();
        assert_eq!(rep.metrics.ledger.requests, trace.len() as u64);
        assert_eq!(rep.metrics.per_shard.len(), 4);
        assert!(rep.metrics.ledger.total() > 0.0);
        assert!(rep.requests_per_sec > 0.0);
        // Every shard saw only its own servers' traffic.
        for s in &rep.metrics.per_shard {
            let expected = trace
                .requests
                .iter()
                .filter(|r| r.server as usize % 4 == s.shard)
                .count() as u64;
            assert_eq!(s.served, expected, "shard {} served wrong subset", s.shard);
        }
        assert!(rep.row().contains("shards=4"));
    }
}
