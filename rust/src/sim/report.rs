//! Simulation reports: per-run cost breakdown + operational statistics,
//! serializable for the experiment harness.

use crate::algo::CachePolicy;
use crate::cache::CostLedger;
use crate::trace::model::Trace;
use crate::util::{Histogram, Json};

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub name: String,
    pub trace: String,
    pub n_requests: usize,
    pub ledger: CostLedger,
    /// Clique-size distribution; `None` when the policy does not track
    /// packing (NoPacking, OPT).
    pub clique_hist: Option<Histogram>,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
}

impl SimReport {
    pub fn collect(policy: &dyn CachePolicy, trace: &Trace, wall_secs: f64) -> Self {
        Self::from_parts(policy, &trace.name, trace.len(), wall_secs)
    }

    /// Build a report without a materialized trace — the streaming
    /// driver's form: provenance and request count come from the stream
    /// ([`TraceMeta`](crate::trace::stream::TraceMeta) + served count).
    pub fn from_parts(
        policy: &dyn CachePolicy,
        trace_name: &str,
        n_requests: usize,
        wall_secs: f64,
    ) -> Self {
        let ledger: CostLedger = policy.ledger().clone();
        Self {
            name: policy.name(),
            trace: trace_name.to_string(),
            n_requests,
            requests_per_sec: n_requests as f64 / wall_secs.max(1e-12),
            ledger,
            clique_hist: policy.clique_sizes(),
            wall_secs,
        }
    }

    /// Total cost C = C_T + C_P.
    pub fn total(&self) -> f64 {
        self.ledger.total()
    }

    /// One human-readable summary row.
    pub fn row(&self) -> String {
        format!(
            "{:<24} total={:>12.1}  C_T={:>12.1}  C_P={:>12.1}  hit={:>5.1}%  eff={:>5.1}%  {:.2}s",
            self.name,
            self.total(),
            self.ledger.c_t,
            self.ledger.c_p,
            self.ledger.hit_rate() * 100.0,
            self.ledger.delivery_efficiency() * 100.0,
            self.wall_secs,
        )
    }

    /// JSON export.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("trace", Json::Str(self.trace.clone())),
            ("n_requests", Json::Num(self.n_requests as f64)),
            ("ledger", self.ledger.to_json()),
            (
                "clique_hist",
                match &self.clique_hist {
                    Some(h) => h.to_json(),
                    None => Json::Null,
                },
            ),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("requests_per_sec", Json::Num(self.requests_per_sec)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::NoPacking;
    use crate::config::AkpcConfig;
    use crate::trace::generator::netflix_like;

    #[test]
    fn report_rows_render() {
        let cfg = AkpcConfig::default();
        let trace = netflix_like(30, 10, 1000, 1);
        let rep = crate::sim::run(&mut NoPacking::new(&cfg), &trace, 200);
        let row = rep.row();
        assert!(row.contains("NoPacking"));
        assert!(rep.requests_per_sec > 0.0);
        // NoPacking does not pack: the histogram is "not tracked", not
        // an empty distribution.
        assert!(rep.clique_hist.is_none());
        let json = rep.to_json().to_string();
        assert!(json.contains("\"c_t\""));
        assert!(json.contains("\"clique_hist\":null"));
        crate::util::json::parse(&json).unwrap();
    }
}
