//! Extended policy families beyond the paper's evaluation set
//! (DESIGN.md §15) — registered through the same
//! [`PolicyRegistry::register`](crate::run::PolicyRegistry::register)
//! path any downstream extension uses, so they appear in
//! `akpc policy list`, resolve by name in `akpc run`/`akpc scenario`,
//! and are swept by `akpc exp policies`:
//!
//! | policy | idea | reference |
//! |---|---|---|
//! | [`Predictive`] | EWMA co-access forecast feeds clique generation | Choi et al. (PAPERS.md) |
//! | [`BundleOpt`] | per-request missing-bundle packed fetch | Qin & Etesami (PAPERS.md) |
//!
//! Both are *online* policies on the shared Table-I cost model, which
//! keeps every cross-policy comparison apples-to-apples; the
//! cross-policy differential harness (`tests/policy.rs`) pins their
//! ledger identities, determinism, and ordering against the builtin
//! field. This directory is in akpc-lint L2 scope (DESIGN.md §11):
//! learned state must never leak hash-iteration order into packing
//! decisions.

pub mod bundle_opt;
pub mod predictive;

pub use bundle_opt::BundleOpt;
pub use predictive::{CoAccessPredictor, Predictive};
