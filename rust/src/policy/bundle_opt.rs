//! **BundleOpt** — online file-bundle caching in the style of Qin &
//! Etesami ("Optimal Online Algorithms for File-Bundle Caching and
//! Generalization to Distributed Caching", PAPERS.md), mapped onto this
//! repo's transfer-plus-rent cost model (DESIGN.md §15.2).
//!
//! Qin–Etesami treat each request as a *file bundle* that must be served
//! in full, and prove an online algorithm that fetches the missing part
//! of the bundle in one batched transfer is constant-competitive against
//! the offline optimum for the bundle-miss cost. Translated to the
//! paper's Table-I cost model, "one batched transfer" is exactly a packed
//! transfer of the request's missing items: `(1 + (m−1)·α)·λ` for `m`
//! missing items instead of NoPacking's `m·λ`. Rent is charged per cached
//! item for the Δt expiry window, identical to every other policy here
//! (Algorithm 6 without forced retention — bundles are per-request, so no
//! clique is ever "current").
//!
//! The pointwise dominance argument (DESIGN.md §15.2): on every request,
//! BundleOpt's transfer charge `(1+(m−1)α)λ ≤ m·λ` equals or undercuts
//! NoPacking's on the same miss set, and its rent stream is identical —
//! so `total(BundleOpt) ≤ total(NoPacking)` on *every* trace, which is
//! what makes it a strong competitive baseline for `akpc exp policies`.
//! Unlike AKPC it never packs *across* requests (no learned cliques), so
//! items co-accessed in different requests of one session still pay
//! separate transfers — the gap AKPC's clique discovery closes.

use std::collections::HashSet;

use crate::algo::CachePolicy;
use crate::cache::{CacheState, CostLedger, CostModel};
use crate::config::AkpcConfig;
use crate::trace::model::Request;
use crate::util::{clique_key, Histogram};

/// Online file-bundle caching baseline (Qin–Etesami mapping).
#[derive(Debug)]
pub struct BundleOpt {
    cost: CostModel,
    ledger: CostLedger,
    cache: CacheState,
    /// Fetched-bundle sizes per transfer (reported via `clique_sizes`).
    hist: Histogram,
    /// Always empty: per-request bundles have no `Clique(W)`, so
    /// Algorithm 6 never force-retains a copy (no retention rent either).
    no_current: HashSet<u64>,
}

impl BundleOpt {
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self {
            cost: CostModel::from_config(cfg),
            ledger: CostLedger::default(),
            cache: CacheState::new(),
            hist: Histogram::new(),
            no_current: HashSet::new(),
        }
    }
}

impl CachePolicy for BundleOpt {
    fn name(&self) -> String {
        "BundleOpt".into()
    }

    fn handle_request(&mut self, r: &Request) {
        let now = r.time;
        // Items are cached individually (bundle membership is per-request,
        // not a persistent pack), so expiry runs with no current cliques:
        // nothing is retained and no retention rent accrues.
        self.cache
            .process_expirations(now, &self.no_current, self.cost.delta_t);

        let new_exp = now + self.cost.delta_t;
        let mut missing: u32 = 0;
        for &d in &r.items {
            let key = clique_key(&[d]);
            if self.cache.is_cached(key, r.server, now) {
                // Cached part of the bundle: extend, charge the extension.
                let prev = self.cache.extend(key, r.server, new_exp);
                self.ledger.c_p += self.cost.caching(1, new_exp - prev);
            } else {
                // Missing part: fetched below as one packed bundle.
                missing += 1;
                self.cache.insert(key, 1, r.server, new_exp);
                self.ledger.c_p += self.cost.caching(1, self.cost.delta_t);
            }
        }
        if missing > 0 {
            // The Qin–Etesami step: ONE batched transfer for the whole
            // missing sub-bundle, at the packed rate of Table I.
            self.ledger.c_t += self.cost.transfer_packed(missing);
            self.ledger.transfers += 1;
            self.hist.record(missing);
            self.ledger.misses += 1;
        } else {
            self.ledger.full_hits += 1;
        }
        let k = r.items.len() as u64;
        self.ledger.items_delivered += k;
        self.ledger.items_requested += k;
        self.ledger.requests += 1;
    }

    fn ledger(&self) -> &CostLedger {
        &self.ledger
    }

    fn clique_sizes(&self) -> Option<Histogram> {
        Some(self.hist.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::NoPacking;

    fn req(items: &[u32], server: u32, t: f64) -> Request {
        Request::new(items.to_vec(), server, t)
    }

    #[test]
    fn singleton_miss_matches_no_packing() {
        // A one-item bundle is a singleton transfer: λ + μΔt = 2.
        let cfg = AkpcConfig::default();
        let mut p = BundleOpt::new(&cfg);
        p.handle_request(&req(&[3], 0, 0.0));
        assert!((p.ledger().c_t - 1.0).abs() < 1e-12);
        assert!((p.ledger().c_p - 1.0).abs() < 1e-12);
        assert_eq!(p.ledger().misses, 1);
    }

    #[test]
    fn multi_item_bundle_is_one_packed_transfer() {
        // 3-item bundle: C_T = (1+2α)λ = 2.6, not 3λ; one transfer.
        let cfg = AkpcConfig::default();
        let mut p = BundleOpt::new(&cfg);
        p.handle_request(&req(&[1, 2, 3], 0, 0.0));
        assert_eq!(p.ledger().transfers, 1);
        assert!((p.ledger().c_t - 2.6).abs() < 1e-12);
        assert!((p.ledger().c_p - 3.0).abs() < 1e-12);
    }

    #[test]
    fn partial_hit_fetches_only_missing_items() {
        let cfg = AkpcConfig::default();
        let mut p = BundleOpt::new(&cfg);
        p.handle_request(&req(&[1], 0, 0.0));
        // 0.5 later: 1 is cached, {2,3} missing -> packed pair (1+α)λ.
        let t0 = p.ledger().c_t;
        p.handle_request(&req(&[1, 2, 3], 0, 0.5));
        assert!((p.ledger().c_t - t0 - 1.8).abs() < 1e-12);
        assert_eq!(p.ledger().transfers, 2);
        assert_eq!(p.ledger().misses, 2);
    }

    #[test]
    fn full_hit_within_dt_charges_only_extension() {
        let cfg = AkpcConfig::default();
        let mut p = BundleOpt::new(&cfg);
        p.handle_request(&req(&[1, 2], 0, 0.0));
        let (t0, p0) = (p.ledger().c_t, p.ledger().c_p);
        p.handle_request(&req(&[1, 2], 0, 0.4));
        assert_eq!(p.ledger().c_t, t0);
        assert!((p.ledger().c_p - p0 - 2.0 * 0.4).abs() < 1e-12);
        assert_eq!(p.ledger().full_hits, 1);
    }

    #[test]
    fn expired_items_refetched() {
        let cfg = AkpcConfig::default();
        let mut p = BundleOpt::new(&cfg);
        p.handle_request(&req(&[1], 0, 0.0));
        p.handle_request(&req(&[1], 0, 5.0)); // far past Δt = 1
        assert_eq!(p.ledger().transfers, 2);
    }

    #[test]
    fn dominates_no_packing_pointwise() {
        // The §15.2 dominance argument, checked on a mixed trace: on every
        // prefix BundleOpt's total never exceeds NoPacking's.
        let cfg = AkpcConfig::default();
        let mut b = BundleOpt::new(&cfg);
        let mut n = NoPacking::new(&cfg);
        let reqs = [
            req(&[1, 2, 3], 0, 0.0),
            req(&[2, 4], 0, 0.3),
            req(&[1, 2, 3], 1, 0.4),
            req(&[5], 0, 2.0),
            req(&[1, 2, 3, 4, 5], 0, 2.1),
            req(&[1, 2], 0, 9.0),
        ];
        for r in &reqs {
            b.handle_request(r);
            n.handle_request(r);
            assert!(
                b.ledger().total() <= n.ledger().total() + 1e-9,
                "BundleOpt {} > NoPacking {} after t={}",
                b.ledger().total(),
                n.ledger().total(),
                r.time
            );
        }
        // And strictly cheaper once any multi-item bundle missed.
        assert!(b.ledger().total() < n.ledger().total() - 1e-9);
    }

    #[test]
    fn accounting_identities_hold() {
        let cfg = AkpcConfig::default();
        let mut p = BundleOpt::new(&cfg);
        for i in 0..40u32 {
            p.handle_request(&req(&[i % 5, (i * 3) % 5], (i % 2), i as f64 * 0.3));
        }
        let l = p.ledger();
        assert_eq!(l.full_hits + l.misses, l.requests);
        assert!(l.transfers >= l.misses);
        assert!(l.c_p >= 0.0 && l.c_t >= 0.0);
        assert_eq!(l.items_delivered, l.items_requested);
    }
}
