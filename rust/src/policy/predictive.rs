//! **Predictive** — EWMA co-access prediction ahead of the access
//! (DESIGN.md §15.1), following Choi et al. ("Learning-based Dynamic
//! Cache Management in a Cloud", PAPERS.md).
//!
//! AKPC packs from the *observed* CRM of the last few batches; this
//! policy packs from a *forecast*. A [`CoAccessPredictor`] is fit online
//! over the CRM window history: every window tick builds the observation
//! CRM (the same `crm/` CSR rows AKPC consumes — they are the feature
//! source), folds its per-pair co-access weights into exponentially
//! decayed affinity scores, and synthesizes a *predicted* CRM whose
//! normalized scores act as predicted-affinity priors for
//! `CliqueSet::generate` (adjust → form → split → `merge_approx`). Stale
//! signal decays at every window boundary ([`DECAY`]), so a pair that
//! stops co-occurring fades out of the packing instead of pinning a dead
//! clique forever, while a long-lived pairing accumulates confidence that
//! one noisy window cannot erase — the prediction is *ahead* of the next
//! access in exactly Choi et al.'s sense.
//!
//! Determinism (akpc-lint L2 — this directory is in scope): all learned
//! state lives in a `BTreeMap`, every iteration walks sorted keys, and
//! the synthesized CRM goes through the same `CrmWindow::from_entries`
//! assembly the engines use.

use std::collections::BTreeMap;

use crate::algo::{CachePolicy, PackedCacheCore};
use crate::cache::{CostLedger, CostModel};
use crate::clique::CliqueSet;
use crate::config::AkpcConfig;
use crate::crm::{diff_windows, CrmBuilder, CrmWindow, NativeCrmBuilder};
use crate::trace::model::Request;
use crate::util::Histogram;

/// Per-window-boundary decay of learned affinities (EWMA retention).
pub const DECAY: f64 = 0.7;

/// Scores below this after decay are dropped (bounds the model to pairs
/// with live signal; `DECAY^9 ≈ 0.04`, so ~9 silent windows forget a
/// single observation).
const PRUNE_EPS: f64 = 0.05;

/// Online EWMA co-access predictor over CRM window history.
///
/// Scores are keyed by unordered item pair `(u, v)` with `u < v`, in a
/// `BTreeMap` so every walk is id-ordered (no hash-order leakage — L2).
/// Feeding it the per-window CRM rather than raw requests keeps the
/// feature pipeline identical to AKPC's (sessionize → co-occurrence →
/// top-p% → min-max normalize), so predicted and observed windows live
/// on the same [0, 1] scale.
#[derive(Debug, Default, Clone)]
pub struct CoAccessPredictor {
    scores: BTreeMap<(u32, u32), f64>,
}

impl CoAccessPredictor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of pairs with live (un-pruned) signal.
    pub fn len(&self) -> usize {
        self.scores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scores.is_empty()
    }

    /// One window boundary: decay every learned affinity and prune dust.
    pub fn decay(&mut self) {
        for v in self.scores.values_mut() {
            *v *= DECAY;
        }
        self.scores.retain(|_, v| *v > PRUNE_EPS);
    }

    /// Fold one observation window's CSR rows into the learned scores
    /// (decay first — the window boundary is where stale signal fades).
    /// Sub-threshold co-access neighbors count too: the predictor sees
    /// the weighted CRM, not just its binarization.
    pub fn absorb_crm(&mut self, crm: &CrmWindow) {
        self.decay();
        for &u in &crm.active {
            for (v, w, _) in crm.neighbors(u) {
                if v > u && w > 0.0 {
                    *self.scores.entry((u, v)).or_default() += w as f64;
                }
            }
        }
    }

    /// Current affinity score of an item pair (0 when unknown).
    pub fn score(&self, a: u32, b: u32) -> f64 {
        if a == b {
            return 0.0;
        }
        let key = if a < b { (a, b) } else { (b, a) };
        self.scores.get(&key).copied().unwrap_or(0.0)
    }

    /// Synthesize the *predicted* CRM for the next window: scores
    /// max-normalized to [0, 1], binarized at `theta` — the same edge
    /// rule the native engine applies to observed weights, so
    /// `CliqueSet::generate` consumes predictions and observations
    /// interchangeably.
    pub fn predicted_window(&self, theta: f32) -> CrmWindow {
        if self.scores.is_empty() {
            return CrmWindow::default();
        }
        let max = self
            .scores
            .values()
            .fold(0.0f64, |m, &v| if v > m { v } else { m });
        if max <= 0.0 {
            return CrmWindow::default();
        }
        // Active set + id→row in one sorted pass (BTreeMap key order).
        let mut active: Vec<u32> = Vec::new();
        for &(u, v) in self.scores.keys() {
            active.push(u);
            active.push(v);
        }
        active.sort_unstable();
        active.dedup();
        let row_of = |item: u32| -> u32 {
            active.binary_search(&item).expect("scored item is active") as u32
        };
        let mut entries = Vec::with_capacity(self.scores.len() * 2);
        for (&(u, v), &s) in &self.scores {
            let w = (s / max) as f32;
            let is_edge = w > theta;
            entries.push(crate::crm::CsrEntry {
                row: row_of(u),
                id: v,
                w,
                is_edge,
            });
            entries.push(crate::crm::CsrEntry {
                row: row_of(v),
                id: u,
                w,
                is_edge,
            });
        }
        CrmWindow::from_entries(active, entries)
    }
}

/// The predictive policy: Algorithm 5/6 serving over cliques generated
/// from the predictor's forecast instead of the observed window.
pub struct Predictive {
    cfg: AkpcConfig,
    core: PackedCacheCore,
    builder: Box<dyn CrmBuilder>,
    predictor: CoAccessPredictor,
    /// Diff base: last window's *predicted* CRM.
    prev_pred: CrmWindow,
    cliques: CliqueSet,
    hist: Histogram,
}

impl Predictive {
    /// Predictive with the native CRM engine for the observation windows.
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self::with_builder(cfg, Box::new(NativeCrmBuilder))
    }

    /// Predictive with an explicit CRM engine (the registry injects the
    /// runtime's choice, same as AKPC).
    pub fn with_builder(cfg: &AkpcConfig, builder: Box<dyn CrmBuilder>) -> Self {
        Self {
            cfg: cfg.clone(),
            core: PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy),
            builder,
            predictor: CoAccessPredictor::new(),
            prev_pred: CrmWindow::default(),
            cliques: CliqueSet::new(),
            hist: Histogram::new(),
        }
    }

    /// The live predictor (inspection / tests).
    pub fn predictor(&self) -> &CoAccessPredictor {
        &self.predictor
    }

    /// Current clique set (inspection / tests).
    pub fn cliques(&self) -> &CliqueSet {
        &self.cliques
    }
}

impl CachePolicy for Predictive {
    fn name(&self) -> String {
        "Predictive".into()
    }

    fn handle_request(&mut self, r: &Request) {
        self.core.handle_request(r);
    }

    fn end_batch(&mut self, batch: &[Request]) {
        // Observe: sessionize the batch and build its CRM — identical
        // feature pipeline to AKPC's Event 1.
        let gap = self.cfg.session_gap_frac * self.cfg.delta_t();
        let transactions = crate::crm::sessionize(batch, gap);
        let observed = self.builder.build(
            &transactions,
            self.cfg.n_items,
            self.cfg.theta,
            self.cfg.crm_top_frac,
        );
        // Learn: decay + fold the observation into the EWMA scores.
        self.predictor.absorb_crm(&observed);
        // Predict: synthesize next window's CRM and regenerate cliques
        // from it (predicted-affinity priors into adjust/form/split/ACM).
        let predicted = self.predictor.predicted_window(self.cfg.theta);
        let delta = diff_windows(&self.prev_pred, &predicted);
        self.cliques = CliqueSet::generate(
            &self.cliques,
            &predicted,
            &delta,
            self.cfg.omega,
            self.cfg.gamma_approx,
            self.cfg.clique_splitting,
            self.cfg.approx_merging,
        );
        self.prev_pred = predicted;
        for c in self.cliques.iter() {
            self.hist.record(c.len() as u32);
        }
        self.core.set_cliques(self.cliques.iter());
    }

    fn ledger(&self) -> &CostLedger {
        &self.core.ledger
    }

    fn clique_sizes(&self) -> Option<Histogram> {
        Some(self.hist.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(items: &[u32], server: u32, t: f64) -> Request {
        Request::new(items.to_vec(), server, t)
    }

    fn test_cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 4,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    /// A window that makes {0,1,2} a strong bundle (mirrors algo::akpc).
    fn bundle_window(t0: f64) -> Vec<Request> {
        let mut w = Vec::new();
        for i in 0..20 {
            w.push(req(&[0, 1, 2], 0, t0 + i as f64 * 0.01));
            w.push(req(&[5, 6], 1, t0 + i as f64 * 0.01));
        }
        w
    }

    #[test]
    fn learns_cliques_from_predicted_window() {
        let cfg = test_cfg();
        let mut p = Predictive::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        assert_eq!(p.cliques().clique_of(0).unwrap(), &[0, 1, 2]);
        assert_eq!(p.cliques().clique_of(5).unwrap(), &[5, 6]);
        p.cliques().check_invariants().unwrap();
    }

    #[test]
    fn serves_predicted_clique_on_single_item_request() {
        let cfg = test_cfg();
        let mut p = Predictive::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        p.handle_request(&req(&[0], 2, 10.0));
        assert_eq!(p.ledger().items_delivered, 3);
        assert_eq!(p.ledger().items_requested, 1);
        assert!((p.ledger().c_t - 2.6).abs() < 1e-12);
    }

    #[test]
    fn decay_forgets_stale_bundles() {
        let cfg = test_cfg();
        let mut p = Predictive::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        let fresh = p.predictor().score(0, 1);
        assert!(fresh > 0.0);
        // Windows with unrelated traffic only: {0,1} decays toward prune.
        for k in 1..12 {
            let w: Vec<Request> = (0..20)
                .map(|i| req(&[8, 9], 0, k as f64 * 100.0 + i as f64 * 0.01))
                .collect();
            p.end_batch(&w);
        }
        assert!(
            p.predictor().score(0, 1) < fresh * 0.2,
            "stale affinity did not decay: {} vs {}",
            p.predictor().score(0, 1),
            fresh
        );
        // The live pair dominates the prediction now.
        assert!(p.predictor().score(8, 9) > p.predictor().score(0, 1));
        assert_eq!(p.cliques().clique_of(8).unwrap(), &[8, 9]);
    }

    #[test]
    fn persistent_signal_survives_one_noisy_window() {
        let cfg = test_cfg();
        let mut p = Predictive::new(&cfg);
        // Three consistent windows build confidence...
        for k in 0..3 {
            p.end_batch(&bundle_window(k as f64 * 100.0));
        }
        // ...one empty window must not unpack the bundle (EWMA memory —
        // the single-window CRM would).
        p.end_batch(&[]);
        assert_eq!(p.cliques().clique_of(0).unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn predictor_scores_are_symmetric_and_zero_on_diagonal() {
        let mut pred = CoAccessPredictor::new();
        let crm = crate::crm::build_native(
            &crate::crm::sessionize(&bundle_window(0.0), 0.05),
            16,
            0.2,
            1.0,
        );
        pred.absorb_crm(&crm);
        assert_eq!(pred.score(0, 1), pred.score(1, 0));
        assert_eq!(pred.score(3, 3), 0.0);
        assert!(pred.score(0, 1) > 0.0);
        assert_eq!(pred.score(0, 9), 0.0);
    }

    #[test]
    fn predicted_window_matches_native_edge_rule() {
        // One absorbed window, scores max-normalized: the strongest pair
        // must be an edge at any θ < 1, and the window must be symmetric.
        let mut pred = CoAccessPredictor::new();
        let crm = crate::crm::build_native(
            &crate::crm::sessionize(&bundle_window(0.0), 0.05),
            16,
            0.2,
            1.0,
        );
        pred.absorb_crm(&crm);
        let w = pred.predicted_window(0.2);
        assert!(w.edge(0, 1) && w.edge(1, 0));
        assert!((w.weight(0, 1) - w.weight(1, 0)).abs() < 1e-6);
        assert_eq!(w.edge_count(), w.edges().len());
        assert!(w.k() >= 4);
    }

    #[test]
    fn empty_predictor_predicts_empty_window() {
        let pred = CoAccessPredictor::new();
        let w = pred.predicted_window(0.2);
        assert_eq!(w.k(), 0);
        assert_eq!(w.edge_count(), 0);
    }
}
