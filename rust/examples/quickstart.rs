//! Quickstart: the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small Netflix-like trace, runs AKPC and the NoPacking
//! baseline through the simulator, and prints the cost breakdown.

use akpc::algo::{Akpc, NoPacking, Opt};
use akpc::config::AkpcConfig;
use akpc::sim;
use akpc::trace::generator::netflix_like;

fn main() -> anyhow::Result<()> {
    // 1. Configuration — defaults reproduce the paper's Table II.
    let cfg = AkpcConfig {
        n_items: 60,
        n_servers: 100,
        ..Default::default()
    };
    cfg.validate()?;
    println!("Δt = ρ·λ/μ = {}", cfg.delta_t());

    // 2. Workload — a synthetic co-access-heavy trace (stand-in for the
    //    paper's Netflix Kaggle trace; see DESIGN.md §2).
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 50_000, 42);
    println!("trace: {} requests over {} servers\n", trace.len(), trace.n_servers);

    // 3. Run policies through the batched-window simulator (Fig. 3).
    let mut akpc = Akpc::new(&cfg); // native CRM engine; see e2e_cdn for XLA
    let rep_akpc = sim::run(&mut akpc, &trace, cfg.batch_size);

    let mut base = NoPacking::new(&cfg);
    let rep_base = sim::run(&mut base, &trace, cfg.batch_size);

    let mut opt = Opt::new(&cfg);
    let rep_opt = sim::run(&mut opt, &trace, cfg.batch_size);

    // 4. Inspect.
    println!("{}", rep_base.row());
    println!("{}", rep_akpc.row());
    println!("{}", rep_opt.row());
    println!(
        "\nAKPC saves {:.1}% of total cost vs NoPacking; is {:.2}x OPT",
        100.0 * (1.0 - rep_akpc.total() / rep_base.total()),
        rep_akpc.total() / rep_opt.total(),
    );
    println!(
        "learned cliques: {} live, mean size {:.2}",
        akpc.cliques().len(),
        // Baselines that don't pack report None here; AKPC always tracks.
        rep_akpc.clique_hist.as_ref().map(|h| h.mean()).unwrap_or(0.0)
    );
    Ok(())
}
