//! Sensitivity mini-study: how the AKPC advantage responds to the
//! cost-model knobs (α, ρ) and the packing knobs (θ, γ, ω) — a compact
//! version of the paper's Figs. 6 & 7 runnable in under a minute.
//!
//! ```bash
//! cargo run --release --example sensitivity
//! ```

use akpc::bench::experiments::{fig6a, fig6b, fig7a, fig7b, fig7c, ExpOptions};
use akpc::bench::sweep::EngineChoice;
use akpc::config::AkpcConfig;

fn main() {
    let opts = ExpOptions {
        n_requests: 30_000,
        engine: EngineChoice::Native,
        seed: 7,
    };
    let cfg = AkpcConfig {
        n_servers: 100,
        ..Default::default()
    };

    println!("(reduced-scale sweeps; full scale via `akpc exp <id>`)\n");
    fig6a(&opts, &cfg).print();
    println!();
    fig6b(&opts, &cfg).print();
    println!();
    fig7a(&opts, &cfg).print();
    println!();
    fig7b(&opts, &cfg).print();
    println!();
    fig7c(&opts, &cfg).print();

    println!("\nReading guide (paper's findings):");
    println!(" - Fig 6(a): all methods converge to NoPacking as α→1;");
    println!(" - Fig 6(b): AKPC's edge grows with ρ (transfers dominate);");
    println!(" - Fig 7:   U-shaped curves with optima near θ≈0.15-0.2, γ≈0.85, ω≈5.");
}
