//! End-to-end driver: the full three-layer stack on a realistic workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cdn
//! ```
//!
//! Proves all layers compose:
//!   * L1/L2 — the AOT-compiled JAX/Pallas CRM pipeline (HLO text) is
//!     loaded and executed by the PJRT CPU client on every window tick
//!     (requires the `xla` feature + artifacts; native fallback otherwise);
//!   * L3 — the sharded coordinator routes requests by ESS to four shard
//!     actors under one clique-generation worker, Python never on the
//!     request path.
//!
//! Replays a 1M-request Netflix-like trace through the online coordinator
//! (XLA engine), then runs the offline baselines on the same trace and
//! reports the paper's headline metric (cost reduction vs PackCache /
//! distance to OPT). Results recorded in EXPERIMENTS.md.

use akpc::algo::{CachePolicy, NoPacking, Opt, PackCache2};
use akpc::config::AkpcConfig;
use akpc::coordinator::{Coordinator, ServeRequest};
use akpc::runtime::CrmEngine;
use akpc::sim;
use akpc::trace::generator::netflix_like;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);

    let cfg = AkpcConfig::default(); // Table II: n=60, m=600, batch=200
    let trace = netflix_like(cfg.n_items, cfg.n_servers, n_requests, cfg.seed);
    println!(
        "e2e: {} requests, n={} items, m={} servers, batch={}",
        trace.len(),
        cfg.n_items,
        cfg.n_servers,
        cfg.batch_size
    );

    // ---- Online serving through the sharded coordinator ----
    let t0 = std::time::Instant::now();
    let coord = Coordinator::start(cfg.clone(), CrmEngine::Xla, 4)?;
    let mut delivered_total: u64 = 0;
    for r in &trace.requests {
        let resp = coord.serve(ServeRequest {
            items: r.items.clone(),
            server: r.server,
            time: Some(r.time),
        })?;
        delivered_total += resp.delivered.len() as u64;
    }
    let metrics = coord.metrics()?;
    let online_secs = t0.elapsed().as_secs_f64();
    println!("\n-- online coordinator --");
    println!("{}", metrics.summary());
    println!(
        "throughput: {:.0} req/s (incl. channel round-trips), delivered {} items",
        trace.len() as f64 / online_secs,
        delivered_total
    );
    println!(
        "clique-gen: {} windows, {:.3}s total ({:.3} ms/tick), engine={}",
        metrics.windows,
        metrics.clique_gen_secs,
        1e3 * metrics.clique_gen_secs / metrics.windows.max(1) as f64,
        metrics.engine
    );
    coord.shutdown();

    // ---- Baselines on the identical trace ----
    println!("\n-- baselines (same trace) --");
    let mut reports = Vec::new();
    for mut p in [
        Box::new(NoPacking::new(&cfg)) as Box<dyn CachePolicy>,
        Box::new(PackCache2::new(&cfg)),
        Box::new(Opt::new(&cfg)),
    ] {
        let rep = sim::run(p.as_mut(), &trace, cfg.batch_size);
        println!("{}", rep.row());
        reports.push(rep);
    }

    let akpc_total = metrics.ledger.total();
    let packcache = reports.iter().find(|r| r.name == "PackCache").unwrap();
    let nopack = reports.iter().find(|r| r.name == "NoPacking").unwrap();
    let opt = reports.iter().find(|r| r.name == "OPT").unwrap();

    println!("\n-- headline (paper: −63% vs PackCache, +15% vs OPT on Netflix) --");
    println!(
        "AKPC total = {:.0}: {:.1}% below PackCache, {:.1}% below NoPacking, {:.1}% above OPT",
        akpc_total,
        100.0 * (1.0 - akpc_total / packcache.total()),
        100.0 * (1.0 - akpc_total / nopack.total()),
        100.0 * (akpc_total / opt.total() - 1.0),
    );
    Ok(())
}
