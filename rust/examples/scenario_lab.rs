//! Scenario Lab demo: run a non-stationary built-in scenario through the
//! phased drivers and print the per-phase policy comparison — how AKPC's
//! adaptive clique machinery behaves when the workload shifts under it
//! (DESIGN.md §7). Everything goes through the unified Run API
//! (DESIGN.md §8): policies by registry name, drivers by `RunSpec`.
//!
//! ```bash
//! cargo run --release --example scenario_lab [scenario] [scale]
//! ```

use akpc::run::{NullObserver, PolicyRegistry, RunSpec};
use akpc::scenario;
use akpc::sim::ReplayMode;

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "flash-crowd".to_string());
    let scale: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);

    let spec = scenario::builtin(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario `{name}` — one of {:?}",
            scenario::builtin_names()))?;
    let registry = PolicyRegistry::builtin();
    let base = RunSpec::new().scenario(spec, scale);
    // Materialize once; `with_policy` rebinds without recompiling the
    // scenario, so the A/B comparison replays the identical workload.
    let prepared = base.clone().policy("akpc").validate(&registry)?;
    println!("{}\n", prepared.describe());

    // Per-phase adaptive-vs-static comparison through the single-leader
    // driver: the interesting column is how the AKPC advantage moves when
    // the phase regime changes.
    let akpc = prepared.run(&registry, &mut NullObserver)?;
    let prepared = prepared.with_policy(&registry, "no-packing")?;
    let baseline = prepared.run(&registry, &mut NullObserver)?;
    print!("{}", akpc.render());
    print!("{}", baseline.render());
    println!("\nper-phase AKPC savings vs NoPacking:");
    for (a, b) in akpc.phases.iter().zip(&baseline.phases) {
        println!(
            "  {:<16} {:>6.1}%",
            a.label,
            100.0 * (1.0 - a.ledger.total() / b.ledger.total().max(1e-12))
        );
    }

    // The same timeline through the sharded online coordinator: the
    // ordered 2-shard replay lands on the same ledger (DESIGN.md §7.3).
    let sharded = base
        .policy("akpc")
        .sharded(2, ReplayMode::Ordered)
        .execute(&registry)?;
    println!(
        "\n2-shard ordered replay: total={:.1} (single-leader {:.1}, diff {:.2e})",
        sharded.total(),
        akpc.total(),
        (sharded.total() - akpc.total()).abs()
    );
    Ok(())
}
