//! Scenario Lab demo: run a non-stationary built-in scenario through the
//! phased drivers and print the per-phase policy comparison — how AKPC's
//! adaptive clique machinery behaves when the workload shifts under it
//! (DESIGN.md §7).
//!
//! ```bash
//! cargo run --release --example scenario_lab [scenario] [scale]
//! ```

use akpc::algo::{Akpc, NoPacking};
use akpc::config::AkpcConfig;
use akpc::runtime::CrmEngine;
use akpc::scenario::{self, run_phased, run_phased_sharded};
use akpc::sim::ReplayMode;

fn main() -> anyhow::Result<()> {
    let name = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "flash-crowd".to_string());
    let scale: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);

    let spec = scenario::builtin(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario `{name}` — one of {:?}",
            scenario::builtin_names()))?;
    let sc = spec.compile(scale)?;
    println!(
        "scenario `{}` at scale {scale}: {} phases / {} requests\n",
        sc.name,
        sc.phases.len(),
        sc.total_requests()
    );

    let cfg = AkpcConfig {
        n_items: sc.n_items,
        n_servers: sc.n_servers,
        ..Default::default()
    };

    // Per-phase adaptive-vs-static comparison through the single-leader
    // driver: the interesting column is how the AKPC advantage moves when
    // the phase regime changes.
    let akpc = run_phased(&mut Akpc::new(&cfg), &sc, cfg.batch_size);
    let baseline = run_phased(&mut NoPacking::new(&cfg), &sc, cfg.batch_size);
    print!("{}", akpc.render());
    print!("{}", baseline.render());
    println!("\nper-phase AKPC savings vs NoPacking:");
    for (a, b) in akpc.phases.iter().zip(&baseline.phases) {
        println!(
            "  {:<16} {:>6.1}%",
            a.label,
            100.0 * (1.0 - a.ledger.total() / b.ledger.total().max(1e-12))
        );
    }

    // The same timeline through the sharded online coordinator: the
    // ordered 2-shard replay lands on the same ledger (DESIGN.md §7.3).
    let sharded = run_phased_sharded(&cfg, CrmEngine::Native, &sc, 2, ReplayMode::Ordered)?;
    println!(
        "\n2-shard ordered replay: total={:.1} (single-leader {:.1}, diff {:.2e})",
        sharded.total_cost(),
        akpc.total_cost(),
        (sharded.total_cost() - akpc.total_cost()).abs()
    );
    Ok(())
}
