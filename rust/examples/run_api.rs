//! Unified Run API smoke tour (DESIGN.md §8): the policy registry, the
//! `RunSpec` builder, both drivers, and the streaming observers — the
//! release-smoke CI job runs this end to end.
//!
//! ```bash
//! cargo run --release --example run_api
//! ```

use akpc::config::AkpcConfig;
use akpc::run::{
    Observer, PhaseEvent, PolicyRegistry, ProgressPrinter, RunSpec, WindowEvent, Workload,
};
use akpc::sim::ReplayMode;
use akpc::trace::generator::TraceKind;

/// A custom observer: tallies events to show the hook points firing.
#[derive(Default)]
struct Tally {
    windows: u64,
    phases: usize,
}

impl Observer for Tally {
    fn on_window(&mut self, _ev: &WindowEvent<'_>) {
        self.windows += 1;
    }

    fn on_phase(&mut self, ev: &PhaseEvent<'_>) {
        self.phases += 1;
        println!("  phase `{}` done: total={:.1}", ev.phase.label, ev.phase.ledger.total());
    }
}

fn main() -> anyhow::Result<()> {
    // 1. The registry: one source of truth for names, factories, and
    //    capability flags (what `akpc policy list` prints).
    let registry = PolicyRegistry::builtin();
    println!("registered policies:");
    for e in registry.iter() {
        println!("  {:<20} [{:<14}] {}", e.name(), e.caps().summary(), e.description());
    }

    let cfg = AkpcConfig {
        n_items: 60,
        n_servers: 100,
        ..Default::default()
    };

    // 2. Single-leader run with a progress observer.
    println!("\nsingle-leader AKPC over a generated Netflix-like trace:");
    let spec = RunSpec::new()
        .config(cfg.clone())
        .workload(Workload::Generated {
            kind: TraceKind::Netflix,
            n_requests: 20_000,
        })
        .policy("akpc");
    let single = spec.run(&registry, &mut ProgressPrinter::new(50))?;
    println!("{}", single.row());

    // 3. The same spec, sharded: ordered 2-shard replay lands on the
    //    single-leader ledger (DESIGN.md §2.3).
    let sharded = spec
        .clone()
        .sharded(2, ReplayMode::Ordered)
        .execute(&registry)?;
    println!("{}", sharded.row());
    let diff = (sharded.total() - single.total()).abs();
    anyhow::ensure!(
        diff <= 1e-9 * single.total().max(1.0),
        "sharded total {} drifted from single-leader {}",
        sharded.total(),
        single.total()
    );
    println!(
        "sharded == single-leader (diff {diff:.2e}); per-shard ledgers: {}",
        sharded.shard_ledgers().len()
    );

    // 4. A scenario workload with a custom observer on the phase hook.
    println!("\nsmoke scenario through the facade:");
    let mut tally = Tally::default();
    let outcome = RunSpec::new()
        .scenario(akpc::scenario::builtin("smoke").expect("smoke is built in"), 1.0)
        .policy("packcache")
        .run(&registry, &mut tally)?;
    println!("{}", outcome.row());
    anyhow::ensure!(tally.phases == outcome.phases.len() && tally.windows > 0);

    // 5. Validation catches driver/policy conflicts before any work.
    let err = RunSpec::new()
        .config(cfg)
        .generated(TraceKind::Netflix, 1_000)
        .policy("opt")
        .sharded(2, ReplayMode::Ordered)
        .execute(&registry)
        .unwrap_err();
    println!("\nconflict rejected as expected: {err}");
    Ok(())
}
