//! Competitive-ratio demonstration (Theorems 1 & 2).
//!
//! ```bash
//! cargo run --release --example adversarial
//! ```
//!
//! Drives the *actual* AKPC machinery (not the closed form) with the
//! Theorem-2 adversary: phases of S fresh items, each belonging to a
//! distinct ω-clique, never re-requested, spaced > Δt apart — and checks
//! the measured cost ratio against the bound
//! (2 + (ω−1)·α·S) / (1 + (S−1)·α).

use akpc::algo::PackedCacheCore;
use akpc::bench::experiments::{
    adversarial_bound_stated, adversarial_ratio,
};
use akpc::cache::CostModel;
use akpc::config::AkpcConfig;
use akpc::trace::model::Request;

fn main() {
    let cfg = AkpcConfig::default();
    let omega = cfg.omega;
    let alpha = cfg.alpha;
    println!("ω = {omega}, α = {alpha}, Δt = {}\n", cfg.delta_t());
    println!(
        "{:<4}{:>14}{:>16}{:>16}",
        "S", "simulated", "derived bound", "paper's stated"
    );

    for s in 1..=cfg.omega {
        // ---- simulate the adversary against the real Algorithm 5 core ----
        let mut core =
            PackedCacheCore::new(CostModel::from_config(&cfg), cfg.charge_policy);
        let phases = 50u32;
        let mut next_item = 0u32;
        let mut opt_cost = 0.0;
        for phase in 0..phases {
            // S fresh items, each in its own ω-clique (adversary fixes the
            // packing the algorithm has learned).
            let cliques: Vec<Vec<u32>> = (0..s)
                .map(|i| {
                    let base = next_item + i * omega;
                    (base..base + omega).collect()
                })
                .collect();
            core.set_cliques(cliques.iter().map(|c| c.as_slice()));
            let items: Vec<u32> = (0..s).map(|i| next_item + i * omega).collect();
            let t = phase as f64 * (cfg.delta_t() * 10.0); // > Δt apart
            core.handle_request(&Request::new(items, 0, t));
            next_item += s * omega;

            // OPT packs the S requested items into one transfer.
            opt_cost += (1.0 + (s as f64 - 1.0) * alpha) * cfg.lambda;
        }
        let measured = core.ledger.total() / opt_cost;
        let (_, derived) = adversarial_ratio(&cfg, s, phases);
        let stated = adversarial_bound_stated(&cfg, s);
        println!("{s:<4}{measured:>14.4}{derived:>16.4}{stated:>16.4}");
        assert!(
            measured <= derived + 1e-9,
            "S={s}: measured ratio exceeds the derived bound!"
        );
    }
    println!("\nAll simulated ratios equal the bound the paper's Case-2.1");
    println!("derivation yields (tight, Thm. 2). The paper's *stated* closed");
    println!("form typo-drops the S on the leading 2 and matches only at S=1");
    println!("(DESIGN.md §6).");
}
