//! Sharded multi-ESS serving demo: replay one trace through 1-, 2-, 4- and
//! 8-shard coordinators and verify the tentpole invariant — per-shard cost
//! ledgers sum exactly (mod float summation order) to the single-leader
//! ledger on the same trace (DESIGN.md §2.3).
//!
//! ```bash
//! cargo run --release --example sharded_serve [n_requests]
//! ```

use akpc::algo::Akpc;
use akpc::config::AkpcConfig;
use akpc::runtime::CrmEngine;
use akpc::sim::{self, replay_sharded, ReplayMode};
use akpc::trace::generator::netflix_like;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(50_000);

    let cfg = AkpcConfig::default(); // Table II: n=60, m=600, batch=200
    let trace = netflix_like(cfg.n_items, cfg.n_servers, n_requests, cfg.seed);
    println!(
        "sharded_serve: {} requests over m={} servers (batch={})",
        trace.len(),
        cfg.n_servers,
        cfg.batch_size
    );

    // Single-leader reference: the offline simulator running the same
    // Algorithm 1 pipeline.
    let mut akpc = Akpc::new(&cfg);
    let reference = sim::run(&mut akpc, &trace, cfg.batch_size);
    println!(
        "single-leader reference: total={:.1} (C_T={:.1} C_P={:.1})",
        reference.total(),
        reference.ledger.c_t,
        reference.ledger.c_p
    );

    println!("\n-- deterministic ordered replay (sync window barrier) --");
    for n_shards in [1usize, 2, 4, 8] {
        let rep = replay_sharded(
            &cfg,
            CrmEngine::Native,
            &trace,
            n_shards,
            ReplayMode::Ordered,
        )?;
        let sum = rep.shard_sum();
        let diff = (sum - reference.total()).abs();
        println!(
            "{}  shard-sum={:.3} diff-vs-leader={:.2e}",
            rep.row(),
            sum,
            diff
        );
        sim::replay::assert_shard_sum_matches(&rep, reference.total());
        for s in &rep.metrics.per_shard {
            println!(
                "    shard {}: served={} total={:.1} retentions={}",
                s.shard,
                s.served,
                s.ledger.total(),
                s.retentions
            );
        }
    }

    println!("\n-- parallel replay (async ticks, throughput mode) --");
    for n_shards in [1usize, 2, 4, 8] {
        let rep = replay_sharded(
            &cfg,
            CrmEngine::Native,
            &trace,
            n_shards,
            ReplayMode::Parallel,
        )?;
        println!("{}", rep.row());
    }
    println!("\nper-shard ledgers sum to the single-leader ledger: OK");
    Ok(())
}
