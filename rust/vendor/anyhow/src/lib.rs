//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so the subset of the
//! `anyhow` API this crate actually uses — [`Result`], [`Error`], and the
//! `anyhow!` / `bail!` / `ensure!` macros — is provided in-tree. Errors are
//! message-only: the source chain is flattened into the message at
//! conversion time (`Display`/`Debug` both print it), which is all the
//! callers rely on.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error. Unlike the real `anyhow::Error` there is no
/// backtrace and no downcasting; the full source chain is captured as text
/// when converting from a `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// The same blanket conversion the real crate provides; it is coherent
// because `Error` itself deliberately does not implement
// `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Self { msg }
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn parse(s: &str) -> crate::Result<u32> {
            let v: u32 = s.parse()?; // From<ParseIntError>
            crate::ensure!(v < 100, "value {v} too large");
            if v == 13 {
                crate::bail!("unlucky");
            }
            Ok(v)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").unwrap_err().to_string().contains("invalid"));
        assert!(parse("200").unwrap_err().to_string().contains("too large"));
        assert_eq!(parse("13").unwrap_err().to_string(), "unlucky");
        let e = crate::anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
        assert_eq!(format!("{e:?}"), "plain 1");
    }
}
